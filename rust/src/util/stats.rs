//! Streaming statistics and percentile kit for metrics and bench reports.
//!
//! No external deps offline, so we ship: Welford mean/variance, an exact
//! reservoir-free percentile sketch (sorted-on-demand buffer, fine at the
//! 10^4–10^6 sample counts our experiments produce), and fixed-width
//! histograms for latency distributions.

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentiles over a retained sample buffer.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Percentiles {
            xs: Vec::new(),
            sorted: true,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile by linear interpolation, q in [0, 100].
    pub fn pct(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let w = rank - lo as f64;
            self.xs[lo] * (1.0 - w) + self.xs[hi] * w
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.pct(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.pct(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.pct(99.0)
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Fixed-width histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            under: 0,
            over: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = (((x - self.lo) / w) as usize).min(n - 1);
            self.buckets[i] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.under + self.over
    }

    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i]
    }

    pub fn overflow(&self) -> u64 {
        self.over
    }

    /// Compact ASCII rendering for reports.
    pub fn render(&self, width: usize) -> String {
        let maxc = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        let w = (self.hi - self.lo) / self.buckets.len() as f64;
        let mut out = String::new();
        for (i, &c) in self.buckets.iter().enumerate() {
            let bar = "#".repeat((c as usize * width) / maxc as usize);
            out.push_str(&format!(
                "{:8.2}-{:8.2} | {:>8} {}\n",
                self.lo + i as f64 * w,
                self.lo + (i + 1) as f64 * w,
                c,
                bar
            ));
        }
        out
    }
}

/// Throughput ratio helper: `x / base` with divide-by-zero guard.
pub fn ratio(x: f64, base: f64) -> f64 {
    if base.abs() < 1e-12 {
        0.0
    } else {
        x / base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_mean_var() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-9);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn running_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Running::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = Running::new();
        let mut b = Running::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_exact() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.pct(0.0) - 1.0).abs() < 1e-9);
        assert!((p.pct(100.0) - 100.0).abs() < 1e-9);
        assert!((p.p99() - 99.01).abs() < 0.011);
    }

    #[test]
    fn percentiles_empty_is_zero() {
        let mut p = Percentiles::new();
        assert_eq!(p.p50(), 0.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.7, 9.9, -1.0, 10.0, 11.0] {
            h.push(x);
        }
        assert_eq!(h.bucket(0), 1);
        assert_eq!(h.bucket(1), 2);
        assert_eq!(h.bucket(9), 1);
        assert_eq!(h.overflow(), 2);
        assert_eq!(h.total(), 7);
        assert!(h.render(20).lines().count() == 10);
    }

    #[test]
    fn ratio_guard() {
        assert_eq!(ratio(1.0, 0.0), 0.0);
        assert!((ratio(4.0, 2.0) - 2.0).abs() < 1e-12);
    }
}
