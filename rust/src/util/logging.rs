//! Leveled stderr logger backing the `log` crate facade (no `env_logger`
//! offline). Level comes from `PERLLM_LOG` (error|warn|info|debug|trace),
//! default `info`.

use log::{Level, LevelFilter, Metadata, Record};
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.4}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; subsequent calls are no-ops. Returns the active
/// level filter.
pub fn init() -> LevelFilter {
    let filter = match std::env::var("PERLLM_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let logger = Box::leak(Box::new(StderrLogger {
            start: Instant::now(),
        }));
        let _ = log::set_logger(logger);
        log::set_max_level(filter);
    });
    filter
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        let a = super::init();
        let b = super::init();
        assert_eq!(a, b);
        log::info!("logger smoke line");
    }
}
