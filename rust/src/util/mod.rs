//! Foundation substrates built from scratch for the offline environment:
//! deterministic PRNG, streaming statistics, a property-testing harness,
//! and a leveled logger. Everything above (sim, scheduler, coordinator)
//! builds on these.

pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
