//! Deterministic PRNG + distribution kit.
//!
//! The offline vendored crate set has no `rand`; scheduling experiments need
//! reproducible streams anyway (every bench row in EXPERIMENTS.md is pinned
//! to a seed), so we ship our own xoshiro256** generator seeded through
//! SplitMix64 — the reference construction from Blackman & Vigna.

/// xoshiro256** — fast, high-quality, 256-bit state, deterministic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so low-entropy seeds (0, 1, 2…) still produce
    /// well-mixed initial states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson inter-arrivals.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (we don't cache the second value —
    /// determinism beats saving one ln/sqrt).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal: handy for heavy-tailed token-length distributions.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Zipf over {0..n-1} with exponent `s` (inverse-CDF by binary search
    /// over the precomputed harmonic table would allocate; for the small n
    /// used in workloads a linear scan is faster).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(2.0, 6.0)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(1.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 8];
        for _ in 0..20_000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[3]);
        assert!(counts[3] > counts[7]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
