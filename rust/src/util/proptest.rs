//! Minimal property-based testing harness (no `proptest` crate offline).
//!
//! Provides what our invariant tests need: seeded random case generation,
//! a fixed case budget, first-failure shrinking by re-generation at smaller
//! "size", and a reproducible failure report that names the seed.
//!
//! ```no_run
//! use perllm::util::proptest::{check, Gen};
//! check("addition commutes", 256, |g: &mut Gen| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Random value source handed to each property case. `size` grows with the
/// case index so early cases are small (fast, easy to debug) and later ones
/// stress larger structures — the proptest/QuickCheck sizing discipline.
pub struct Gen {
    rng: Rng,
    size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Current size hint (grows over the run, >= 1).
    pub fn size(&self) -> usize {
        self.size.max(1)
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vec with length scaled by the current size hint.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let cap = max_len.min(self.size()).max(1);
        let n = self.usize(0, cap);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing test)
/// with the seed and case index on first failure, after attempting a
/// smaller-sized reproduction to report the simplest found counterexample.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base_seed = env_seed().unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        // Size ramps from 1 to 100 over the run.
        let size = 1 + (case as usize * 99) / (cases.max(2) as usize - 1).max(1);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        });
        if let Err(err) = result {
            // Shrink pass: try the same seed at smaller sizes and report the
            // smallest size that still fails.
            let mut min_fail = size;
            for s in 1..size {
                let again = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                });
                if again.is_err() {
                    min_fail = s;
                    break;
                }
            }
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed: case={case} seed={seed:#x} size={size} \
                 min_failing_size={min_fail}\n  reproduce with PERLLM_PROP_SEED={seed}\n  {msg}"
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("PERLLM_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sort idempotent", 64, |g| {
            let mut xs = g.vec(32, |g| g.i64(-100, 100));
            xs.sort_unstable();
            let once = xs.clone();
            xs.sort_unstable();
            assert_eq!(once, xs);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 8, |g| {
            let x = g.i64(0, 10);
            assert!(x > 100, "x={x}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen ranges", 128, |g| {
            let a = g.u64(5, 9);
            assert!((5..=9).contains(&a));
            let b = g.i64(-3, 3);
            assert!((-3..=3).contains(&b));
            let c = g.f64(1.0, 2.0);
            assert!((1.0..2.0).contains(&c));
        });
    }

    #[test]
    fn vec_len_bounded() {
        check("vec bounded", 64, |g| {
            let xs = g.vec(16, |g| g.bool());
            assert!(xs.len() <= 16);
        });
    }
}
