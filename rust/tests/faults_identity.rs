//! Run-identity pins and chaos properties for the fault-injection
//! subsystem (PR 6), in the style of `slo_identity.rs`: the fault layer
//! must be free when unused, must subsume the legacy outage mechanism
//! bit for bit, and must let the nonstationary CS-UCB variants earn
//! their keep under a real incident.
//!
//! Four contracts:
//!
//! 1. **Empty-plan identity** — `simulate_stream_faulted` with
//!    `FaultPlan::default()` reproduces `simulate_stream` to the bit,
//!    including on a config that already carries legacy outages.
//! 2. **Outage subsumption** — a legacy `cfg.with_outages(...)` run and
//!    an outage-free config driven by `FaultPlan::from_outages(...)`
//!    produce bit-identical reports: the fault layer *is* the outage
//!    mechanism now, not a second one beside it.
//! 3. **Chaos comparison** — after a permanent mid-run crash of a
//!    well-learned server behind a lagged health monitor, the
//!    sliding-window and discounted CS-UCB variants hold incident-phase
//!    SLO attainment at least as well as the stationary learner (which
//!    demonstrably suffers).
//! 4. **Generative-schedule properties** — seeded MTTF/MTTR schedules
//!    are reproducible bit for bit, alternate Down/Up per server with
//!    no overlap, repair every window, stay inside the horizon, and
//!    never reshuffle one server's windows when the fleet grows.

use perllm::scheduler::csucb::CsUcb;
use perllm::scheduler::Scheduler;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig, Outage};
use perllm::sim::engine::{
    simulate, simulate_faulted, simulate_stream, simulate_stream_faulted, RunReport,
};
use perllm::sim::faults::FaultAction;
use perllm::sim::{FaultKind, FaultPlan, GenerativeFaults, HealthConfig};
use perllm::util::proptest::{check, Gen};
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig, WorkloadGen};
use std::collections::HashMap;

/// Bit-level equality of two runs over the pinned `RunReport` surface
/// (same discipline as `slo_identity.rs`).
fn assert_runs_bit_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: id order");
        assert_eq!(x.server, y.server, "{label}: placement of {}", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens of {}", x.id);
        assert_eq!(
            x.completed_at.to_bits(),
            y.completed_at.to_bits(),
            "{label}: completion instant of {}",
            x.id
        );
        assert_eq!(
            x.processing_time.to_bits(),
            y.processing_time.to_bits(),
            "{label}: processing time of {}",
            x.id
        );
        assert_eq!(
            x.energy_j.to_bits(),
            y.energy_j.to_bits(),
            "{label}: energy of {}",
            x.id
        );
    }
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.late, b.late, "{label}: late");
    assert_eq!(
        a.success_rate.to_bits(),
        b.success_rate.to_bits(),
        "{label}: success rate"
    );
    assert_eq!(
        a.energy.total_j().to_bits(),
        b.energy.total_j().to_bits(),
        "{label}: total energy"
    );
    assert_eq!(a.events_processed, b.events_processed, "{label}: events");
    assert_eq!(a.stale_events, b.stale_events, "{label}: stale events");
}

fn workload(n: usize, rate: f64, seed: u64) -> WorkloadConfig {
    WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson { rate })
        .with_deadline_range(2.0, 6.0)
        .with_seed(seed)
}

/// Contract 1: the empty plan is free. Both bandwidth modes, and a
/// config that already carries legacy outages (the empty plan must not
/// perturb their replay either).
#[test]
fn empty_fault_plan_is_bit_identical_to_plan_less_run() {
    let wl = workload(1200, 15.0, 42);
    let outages = vec![Outage {
        server: 1,
        start: 10.0,
        end: 25.0,
    }];
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        for with_legacy in [false, true] {
            let mut cfg = ClusterConfig::paper("llama2-7b", mode);
            if with_legacy {
                cfg = cfg.with_outages(outages.clone());
            }
            let empty = FaultPlan::default();
            assert!(empty.is_empty());
            let mut s1 = CsUcb::with_defaults(cfg.n_servers());
            let mut s2 = CsUcb::with_defaults(cfg.n_servers());
            let mut src1 = WorkloadGen::new(&wl);
            let mut src2 = WorkloadGen::new(&wl);
            let a = simulate_stream(&cfg, &mut src1, &mut s1);
            let b = simulate_stream_faulted(&cfg, &empty, &mut src2, &mut s2);
            assert_runs_bit_identical(
                &a,
                &b,
                &format!("empty plan {mode:?} legacy_outages={with_legacy}"),
            );
        }
    }
}

/// Contract 2: `FaultPlan::from_outages` replays the legacy scripted
/// outage list bit-identically — including nested windows, which both
/// paths now resolve through the same depth-counted fault layer.
#[test]
fn from_outages_replays_legacy_outage_runs_bit_identically() {
    let trace = generate(&workload(1500, 15.0, 7));
    let outages = vec![
        Outage {
            server: 2,
            start: 5.0,
            end: 20.0,
        },
        // Nested inside the first window on the same server: the inner
        // end must not resurrect the server early.
        Outage {
            server: 2,
            start: 8.0,
            end: 12.0,
        },
        Outage {
            server: 5,
            start: 30.0,
            end: 45.0,
        },
    ];
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let legacy_cfg = ClusterConfig::paper("llama2-7b", mode).with_outages(outages.clone());
        let plain_cfg = ClusterConfig::paper("llama2-7b", mode);
        let plan = FaultPlan::from_outages(&outages);
        let mut s1 = CsUcb::with_defaults(legacy_cfg.n_servers());
        let mut s2 = CsUcb::with_defaults(plain_cfg.n_servers());
        let a = simulate(&legacy_cfg, &trace, &mut s1);
        let b = simulate_faulted(&plain_cfg, &plan, &trace, &mut s2);
        assert_runs_bit_identical(&a, &b, &format!("from_outages {mode:?}"));
        // Both paths run the same incident accounting.
        let (av_a, av_b) = (
            a.availability.as_ref().expect("legacy outages report"),
            b.availability.as_ref().expect("fault plan reports"),
        );
        assert_eq!(av_a.incidents, av_b.incidents, "{mode:?}: incidents");
        assert_eq!(av_a.attainment, av_b.attainment, "{mode:?}: attainment");
        assert_eq!(
            av_a.incident_start_s.to_bits(),
            av_b.incident_start_s.to_bits()
        );
        assert_eq!(av_a.incident_end_s.to_bits(), av_b.incident_end_s.to_bits());
        assert!(av_a.incidents >= 2, "the windows actually fired");
    }
}

/// Contract 3: the chaos scenario the nonstationary variants exist for.
/// A permanent hard crash of edge server 0 at t=120 (≈1800 requests in:
/// every arm well learned) behind a 15 s-lagged health monitor — for the
/// blind window the scheduler keeps seeing the corpse as healthy, so
/// only its own reward statistics can steer traffic away. The stationary
/// learner's deep pull counts make its means nearly immovable; the
/// windowed and discounted learners forget within ~one window of
/// crash-failure rewards.
#[test]
fn windowed_and_discounted_csucb_weather_a_crash_no_worse_than_stationary() {
    let wl = workload(4000, 15.0, 11);
    let plan = FaultPlan::default()
        .with_event(
            120.0,
            FaultKind::Crash {
                server: 0,
                recover: None,
            },
        )
        .with_health(HealthConfig {
            period_s: 1.0,
            lag_s: 15.0,
        });
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let run = |sched: &mut dyn Scheduler| {
        let mut src = WorkloadGen::new(&wl);
        simulate_stream_faulted(&cfg, &plan, &mut src, sched)
    };
    let mut stationary = CsUcb::with_defaults(cfg.n_servers());
    let mut windowed = CsUcb::windowed(cfg.n_servers(), 50);
    let mut discounted = CsUcb::discounted(cfg.n_servers(), 0.98);
    let stat = run(&mut stationary);
    let wind = run(&mut windowed);
    let disc = run(&mut discounted);

    let av = stat.availability.as_ref().expect("faulted run");
    assert_eq!(av.incidents, 1);
    assert_eq!(av.incident_start_s, 120.0);
    assert!(av.incident_end_s.is_infinite(), "crash is permanent");
    assert!(
        av.failed_in_flight > 0,
        "a busy server's in-flight work dies with it"
    );
    // Permanent crash ⇒ every post-crash completion lands in the
    // "during" bucket; both phases must carry real sample mass.
    assert!(av.attainment[0].total > 500, "pre-incident sample mass");
    assert!(av.attainment[1].total > 500, "incident sample mass");
    let pre = av.attainment[0].rate();
    let during_stat = av.attainment[1].rate();
    assert!(
        during_stat < pre,
        "the crash must hurt the stationary learner: during {during_stat:.3} vs pre {pre:.3}"
    );
    // The acceptance comparison, pinned non-strictly (a strict float
    // inequality would be flaky across calibrations; the strict
    // demonstration is `paper_scale_sim --faults crash`).
    for (name, rep) in [("windowed", &wind), ("discounted", &disc)] {
        let avn = rep.availability.as_ref().expect("faulted run");
        assert_eq!(avn.incidents, 1, "{name}: same incident");
        assert!(avn.attainment[1].total > 500, "{name}: incident mass");
        assert!(
            avn.attainment[1].rate() >= during_stat,
            "{name} CS-UCB recovered slower than stationary: {:.3} vs {during_stat:.3}",
            avn.attainment[1].rate()
        );
    }
}

/// Contract 4a: generative schedules are pure functions of
/// (seed, config) and per server form a strictly alternating sequence of
/// non-overlapping Down/Up windows that all start inside the horizon and
/// all repair.
#[test]
fn generative_schedules_are_deterministic_and_non_overlapping() {
    check("generative fault schedules", 96, |g: &mut Gen| {
        let n_servers = g.usize(1, 8);
        let mttf = g.f64(5.0, 500.0);
        let mttr = g.f64(1.0, 60.0);
        let horizon = g.f64(0.0, 2000.0);
        let seed = g.u64(0, u64::MAX / 2);
        let kill = g.bool();
        // Random distinct target subset; empty means "every server".
        let targets: Vec<usize> = (0..n_servers).filter(|_| g.chance(0.5)).collect();
        let plan = FaultPlan::default().with_generative(GenerativeFaults {
            mttf_s: mttf,
            mttr_s: mttr,
            horizon_s: horizon,
            targets: targets.clone(),
            kill,
        });

        let t1 = plan.materialize(n_servers, n_servers, seed);
        let t2 = plan.materialize(n_servers, n_servers, seed);
        assert_eq!(t1.len(), t2.len(), "same schedule length");
        for ((ta, aa), (tb, ab)) in t1.iter().zip(&t2) {
            assert_eq!(ta.to_bits(), tb.to_bits(), "times reproduce to the bit");
            assert_eq!(aa, ab, "actions reproduce");
        }

        let mut open: HashMap<usize, f64> = HashMap::new();
        let mut last_up: HashMap<usize, f64> = HashMap::new();
        for (t, action) in &t1 {
            match action {
                FaultAction::Down { server, crash } => {
                    assert_eq!(*crash, kill, "windows carry the configured kind");
                    assert!(*t < horizon, "failures only start inside the horizon");
                    if !targets.is_empty() {
                        assert!(targets.contains(server), "untargeted server failed");
                    }
                    assert!(
                        open.insert(*server, *t).is_none(),
                        "server {server} failed again before repairing"
                    );
                    if let Some(up) = last_up.get(server) {
                        assert!(*t >= *up, "window overlaps the previous repair");
                    }
                }
                FaultAction::Up { server, crash } => {
                    assert_eq!(*crash, kill);
                    let down = open
                        .remove(server)
                        .expect("repair must close an open window");
                    assert!(*t >= down, "repair precedes its failure");
                    last_up.insert(*server, *t);
                }
                other => panic!("generative plans emit only Down/Up, got {other:?}"),
            }
        }
        assert!(open.is_empty(), "every window repairs (even past the horizon)");
    });
}

/// Contract 4b: growing the fleet never reshuffles an existing server's
/// windows — each server draws from its own seeded stream, so chaos
/// experiments stay comparable across topology scales.
#[test]
fn generative_schedules_are_stable_under_fleet_growth() {
    check("generative schedules stable under growth", 64, |g: &mut Gen| {
        let n = g.usize(1, 6);
        let seed = g.u64(0, u64::MAX / 2);
        let gen_faults = GenerativeFaults {
            mttf_s: g.f64(10.0, 300.0),
            mttr_s: g.f64(1.0, 30.0),
            horizon_s: g.f64(50.0, 1000.0),
            targets: Vec::new(),
            kill: g.bool(),
        };
        let plan = FaultPlan::default().with_generative(gen_faults);
        let small = plan.materialize(n, n, seed);
        let grown = plan.materialize(n + 2, n + 2, seed);
        let only = |timeline: &[(f64, FaultAction)], s: usize| -> Vec<(u64, FaultAction)> {
            timeline
                .iter()
                .filter(|(_, a)| match a {
                    FaultAction::Down { server, .. } | FaultAction::Up { server, .. } => {
                        *server == s
                    }
                    _ => false,
                })
                .map(|(t, a)| (t.to_bits(), *a))
                .collect()
        };
        for s in 0..n {
            assert_eq!(
                only(&small, s),
                only(&grown, s),
                "server {s}'s windows moved when the fleet grew"
            );
        }
    });
}
