// Known-good twin of a1_bad.rs: the same region rewritten against a
// caller-owned scratch buffer — no allocation inside the markers.
pub fn hot_path(xs: &[f64], out: &mut Vec<f64>) -> usize {
    // lint: no-alloc fixture region
    out.clear();
    out.extend(xs.iter().copied());
    // lint: end-no-alloc
    out.len()
}
