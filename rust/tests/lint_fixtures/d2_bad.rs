// Known-bad fixture for D2 (unordered-iter): iterating a HashMap in a
// deterministic module without an order-insensitivity annotation.
use std::collections::HashMap;

pub fn collect_ids(map: &HashMap<u64, f64>) -> Vec<u64> {
    let mut ids = Vec::new();
    for k in map.keys() {
        ids.push(*k);
    }
    ids
}
