// Known-good twin of d2_bad.rs: hash iteration whose result is
// provably order-free, annotated as such.
use std::collections::HashMap;

pub fn total(map: &HashMap<u64, f64>) -> f64 {
    let mut sum = 0.0;
    // lint: order-insensitive commutative sum; visitation order cannot change the total
    for v in map.values() {
        sum += *v;
    }
    sum
}
