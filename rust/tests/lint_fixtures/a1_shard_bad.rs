// Known-bad fixture modeled on the shard grant loop (sim/shard.rs): a
// mailbox drain that allocates fresh buffers inside the per-grant
// no-alloc region instead of recycling them through the Reply.
pub fn run_granted(pending: &[(f64, u64)], limit: f64) -> usize {
    // lint: no-alloc per-shard grant window
    let mut executed = Vec::new();
    for &(t, stamp) in pending {
        if t < limit {
            executed.push(stamp);
        }
    }
    let keys: Vec<u64> = executed.iter().map(|s| s >> 32).collect();
    // lint: end-no-alloc
    keys.len()
}
