// Known-good twin of a1_shard_bad.rs: the grant window executes against
// buffers recycled through the orchestrator round trip (the real
// sim/shard.rs contract — Cmd carries them in, Reply hands them back),
// so the region itself never allocates.
pub fn run_granted(pending: &[(f64, u64)], limit: f64, executed: &mut Vec<u64>) -> usize {
    executed.clear();
    // lint: no-alloc per-shard grant window
    for &(t, stamp) in pending {
        if t < limit {
            executed.push(stamp);
        }
    }
    // lint: end-no-alloc
    executed.len()
}
