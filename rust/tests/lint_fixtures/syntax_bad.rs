// Known-bad fixture for lint-syntax: malformed directives are
// themselves diagnostics and never suppress anything.
pub fn annotated() -> u32 {
    // lint: allow(p1)
    let v = Some(1).unwrap();
    // lint: allow(p2) no such rule exists
    let w = Some(2).unwrap();
    v + w
}
