// Known-bad fixture for D1 (wall-clock): ambient time and entropy reads
// outside coordinator/ and util/logging.rs. Linted under a virtual
// `sim/` path by tests/lint.rs; never compiled.
use std::time::Instant;

pub fn sample_now() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn seed_from_os() -> u64 {
    from_entropy()
}
