// Known-bad fixture for D3 (raw-seed), session flavor: a conversation
// generator seeding its side-stream from the workload seed directly —
// exactly the bug that would let enabling sessions perturb (or replay)
// the single-turn base stream.
use crate::util::rng::Rng;

pub fn session_stream(workload_seed: u64) -> Rng {
    Rng::new(workload_seed)
}
