// Known-good twin of d1_bad.rs: the same wall-clock read, justified with
// a trailing `allow(wall-clock)` annotation.
use std::time::Instant;

pub fn sample_now() -> f64 {
    let t0 = Instant::now(); // lint: allow(wall-clock) fixture: measures host throughput only
    t0.elapsed().as_secs_f64()
}
