// Known-bad fixture for A1 (alloc): allocating calls inside a declared
// `// lint: no-alloc` region.
pub fn hot_path(xs: &[f64]) -> String {
    // lint: no-alloc fixture region
    let mut out = Vec::new();
    for x in xs {
        out.push(*x);
    }
    let label = format!("{} items", out.len());
    // lint: end-no-alloc
    label
}
