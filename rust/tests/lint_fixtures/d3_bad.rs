// Known-bad fixture for D3 (raw-seed): constructing a side-stream RNG
// from a raw seed instead of the `seed ^ <X>_STREAM_SALT` idiom.
use crate::util::rng::Rng;

pub fn make_side_stream(seed: u64) -> Rng {
    Rng::new(seed)
}
