// Known-good twin of d3_bad.rs: the salted-stream idiom D3 exists to
// enforce.
use crate::util::rng::Rng;

const FIXTURE_STREAM_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

pub fn make_side_stream(seed: u64) -> Rng {
    Rng::new(seed ^ FIXTURE_STREAM_SALT)
}
