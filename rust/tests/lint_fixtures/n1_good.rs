// Known-good twin of n1_bad.rs: the same comparisons, each annotated
// with the PR-5 convention that slack chains bottom out at -inf.
pub fn worst_slack(xs: &[f64]) -> f64 {
    let mut slack = f64::INFINITY;
    for x in xs {
        // lint: allow(nan-cmp) slack inputs bottom out at -inf, never NaN
        slack = slack.min(*x);
    }
    slack
}

pub fn later(a: f64, b: f64) -> f64 {
    // lint: allow(p1, n1) both operands are finite by construction
    if a.partial_cmp(&b).unwrap() == std::cmp::Ordering::Less {
        b
    } else {
        a
    }
}
