// Known-good twin of p1_bad.rs: the same unwrap carrying a justified
// standalone annotation.
pub fn pick_first(xs: &[f64]) -> f64 {
    // lint: allow(p1) caller guarantees a non-empty slice
    let first = xs.first().unwrap();
    *first
}
