// Known-bad fixture for P1 (panic): unjustified unwrap and panic! in a
// module where a stray panic kills a million-request simulation.
pub fn pick_first(xs: &[f64]) -> f64 {
    let first = xs.first().unwrap();
    if xs.len() > 3 {
        panic!("too many");
    }
    *first
}
