// Known-good twin of d3_session_bad.rs: the salted session side-stream
// idiom `workload::sessions` actually uses — one xor constant per
// stream, so the conversation chains and the base workload can never
// share (or shift) a RNG sequence.
use crate::util::rng::Rng;

pub const SESSION_STREAM_SALT: u64 = 0x5E55_10C4_57A1;

pub fn session_stream(workload_seed: u64) -> Rng {
    Rng::new(workload_seed ^ SESSION_STREAM_SALT)
}
