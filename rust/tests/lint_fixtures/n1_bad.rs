// Known-bad fixture for N1 (nan-cmp): bare f64 min on a slack-typed
// value (silently absorbs NaN) and a partial_cmp().unwrap() chain.
pub fn worst_slack(xs: &[f64]) -> f64 {
    let mut slack = f64::INFINITY;
    for x in xs {
        slack = slack.min(*x);
    }
    slack
}

pub fn later(a: f64, b: f64) -> f64 {
    if a.partial_cmp(&b).unwrap() == std::cmp::Ordering::Less {
        b
    } else {
        a
    }
}
