//! Differential tests for the calendar-queue event structure.
//!
//! `sim::time::EventQueue` (calendar buckets, O(1) amortized) must be
//! observationally identical to `sim::time::HeapEventQueue` (the retained
//! binary-heap implementation, kept as the executable specification):
//! same pop order — FIFO on exact time ties included — same clock, same
//! past-date clamping, same `processed`/`stale`/`peak_len` accounting.
//! The property test replays randomized operation sequences against both
//! side by side; the scenario tests pin the access patterns the DES
//! actually produces (same-instant bursts, far-future outage horizons,
//! monotone pop-push interleaving). A 10x-topology streaming run then
//! checks the scale property the calendar queue exists for: a bounded
//! event heap at 60 servers and ~10x paper arrival rate.

use perllm::scheduler::csucb::CsUcb;
use perllm::sim::cluster::BandwidthMode;
use perllm::sim::engine::simulate_stream;
use perllm::sim::time::{EventQueue, HeapEventQueue};
use perllm::sim::topology::TopologyConfig;
use perllm::util::proptest::{check, Gen};
use perllm::workload::generator::{ArrivalProcess, WorkloadConfig, WorkloadGen};

/// Pop both queues once and demand bit-identical observations.
fn pop_both(cal: &mut EventQueue<u64>, heap: &mut HeapEventQueue<u64>) {
    let a = cal.pop();
    let b = heap.pop();
    match (a, b) {
        (None, None) => {}
        (Some((ta, ea)), Some((tb, eb))) => {
            assert_eq!(ta.to_bits(), tb.to_bits(), "pop times diverged");
            assert_eq!(ea, eb, "pop order diverged at t={ta}");
        }
        (a, b) => panic!("emptiness diverged: calendar {a:?} vs heap {b:?}"),
    }
    assert_eq!(cal.now().to_bits(), heap.now().to_bits());
    assert_eq!(cal.len(), heap.len());
    assert_eq!(cal.processed(), heap.processed());
}

/// One randomized operation sequence applied to both implementations.
fn run_case(g: &mut Gen) {
    let mut cal: EventQueue<u64> = EventQueue::new();
    let mut heap: HeapEventQueue<u64> = HeapEventQueue::new();
    // Remembered push times so later pushes can reuse one bit-for-bit
    // (the FIFO tie-break case a float generator would otherwise
    // essentially never produce).
    let mut seen_times: Vec<f64> = Vec::new();
    let mut next_val = 0u64;
    let ops = g.usize(1, 20 + 20 * g.size());
    for _ in 0..ops {
        let roll = g.f64(0.0, 1.0);
        if roll < 0.55 {
            // Push, drawn from the regimes the DES produces.
            let t = if !seen_times.is_empty() && g.chance(0.25) {
                // Exact repeat: same-instant burst / FIFO tie.
                *g.pick(&seen_times)
            } else if g.chance(0.1) {
                // Past-dated (clamps to now in both).
                (cal.now() - g.f64(0.0, 5.0)).max(0.0)
            } else if g.chance(0.05) {
                // Far-future horizon (outage end): exercises the
                // calendar's direct-search fallback and width sampling.
                g.f64(1.0e5, 1.0e9)
            } else if g.chance(0.5) {
                // Dense near-term completions.
                cal.now() + g.f64(0.0, 1.0e-2)
            } else {
                cal.now() + g.f64(0.0, 10.0)
            };
            seen_times.push(t);
            cal.push_at(t, next_val);
            heap.push_at(t, next_val);
            next_val += 1;
            assert_eq!(cal.len(), heap.len());
            assert_eq!(cal.peak_len(), heap.peak_len());
        } else if roll < 0.9 {
            pop_both(&mut cal, &mut heap);
        } else {
            // Stale accounting is pure bookkeeping; mirror it anyway.
            cal.note_stale();
            heap.note_stale();
            assert_eq!(cal.stale(), heap.stale());
        }
    }
    // Drain to empty: the full residual orders must agree.
    while !cal.is_empty() || !heap.is_empty() {
        pop_both(&mut cal, &mut heap);
    }
    pop_both(&mut cal, &mut heap); // both stay empty
    assert_eq!(cal.peak_len(), heap.peak_len());
    assert_eq!(cal.stale(), heap.stale());
    assert!((cal.stale_ratio() - heap.stale_ratio()).abs() < 1e-15);
}

#[test]
fn calendar_queue_matches_heap_spec_on_random_sequences() {
    check("calendar queue ≡ binary heap", 192, run_case);
}

/// `push_in` goes through the same clamp/order machinery relative to a
/// moving clock; check it differentially too.
#[test]
fn calendar_queue_matches_heap_spec_with_relative_pushes() {
    check("calendar push_in ≡ heap push_in", 96, |g: &mut Gen| {
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapEventQueue<u64> = HeapEventQueue::new();
        let mut v = 0u64;
        for _ in 0..g.usize(1, 10 + 10 * g.size()) {
            if g.chance(0.6) {
                let d = if g.chance(0.3) {
                    0.0 // zero-delay: fires at `now`, FIFO after peers
                } else {
                    g.f64(0.0, 2.0)
                };
                cal.push_in(d, v);
                heap.push_in(d, v);
                v += 1;
            } else {
                pop_both(&mut cal, &mut heap);
            }
        }
        while !cal.is_empty() {
            pop_both(&mut cal, &mut heap);
        }
    });
}

/// The DES peeks the queue in tests and diagnostics: peek must name the
/// same next event time as the spec without disturbing state.
#[test]
fn peek_matches_spec() {
    check("calendar peek ≡ heap peek", 64, |g: &mut Gen| {
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapEventQueue<u64> = HeapEventQueue::new();
        for i in 0..g.usize(0, 40) {
            let t = g.f64(0.0, 100.0);
            cal.push_at(t, i as u64);
            heap.push_at(t, i as u64);
            assert_eq!(
                cal.peek_time().map(f64::to_bits),
                heap.peek_time().map(f64::to_bits)
            );
        }
        while !cal.is_empty() {
            assert_eq!(
                cal.peek_time().map(f64::to_bits),
                heap.peek_time().map(f64::to_bits)
            );
            pop_both(&mut cal, &mut heap);
        }
        assert_eq!(cal.peek_time(), None);
        assert_eq!(heap.peek_time(), None);
    });
}

/// Scale check: a 20k-request streaming run on the 60-server EdgeShard
/// preset at capacity-scaled load keeps the event heap bounded by
/// in-flight concurrency, orders of magnitude below the request count —
/// the property that makes 1M-request fleet runs feasible.
#[test]
fn edgeshard_10x_streaming_keeps_event_heap_bounded() {
    let n = 20_000;
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
    let cfg = topo.build();
    let workload = WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson {
            rate: topo.scaled_rate(15.0),
        })
        .with_deadline_range(2.0, 6.0)
        .with_seed(42);
    let mut s = CsUcb::with_defaults(cfg.n_servers());
    let mut source = WorkloadGen::new(&workload);
    let rep = simulate_stream(&cfg, &mut source, &mut s);
    assert_eq!(rep.outcomes.len(), n, "every request resolved");
    assert!(
        rep.peak_event_queue_len < n / 10,
        "event heap scaled with trace length: peak {} on {n} requests",
        rep.peak_event_queue_len
    );
    assert!(rep.events_processed > n as u64);
    assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
}
