//! Streaming-arrival integration tests: the `ArrivalSource` engine path
//! must keep the event heap bounded by in-flight concurrency (not trace
//! length) while producing exactly the results of the materialized-trace
//! path.

use perllm::scheduler::csucb::CsUcb;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::{simulate, simulate_stream};
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig, WorkloadGen};

/// The headline memory guarantee: on a 100k-request run the event-heap
/// high-water mark stays orders of magnitude below the request count.
/// Before the `ArrivalSource` port the engine pre-pushed one `Arrival`
/// event per request, so the peak was >= n by construction.
///
/// This is the suite's most expensive test (~1M debug-mode DES events —
/// a few seconds); the scale is deliberate, it is the acceptance check
/// for the streaming redesign. The release-mode CI smoke gates the same
/// property via `paper_scale_sim --max-peak-event-heap`.
#[test]
fn event_heap_stays_bounded_on_100k_run() {
    let n = 100_000;
    let workload = WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson { rate: 15.0 })
        .with_deadline_range(2.0, 6.0)
        .with_seed(42);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let mut s = CsUcb::with_defaults(cfg.n_servers());
    let mut source = WorkloadGen::new(&workload);
    let rep = simulate_stream(&cfg, &mut source, &mut s);
    assert_eq!(rep.outcomes.len(), n, "every request resolved");
    assert!(
        rep.peak_event_queue_len < n / 10,
        "event heap scaled with trace length: peak {} on {n} requests",
        rep.peak_event_queue_len
    );
    // Sanity: the run actually did something.
    assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
    assert!(rep.events_processed > n as u64, "{} events", rep.events_processed);
}

/// Differential: the streamed generator and the materialized trace drive
/// the engine to identical reports (same events, same outcomes, same
/// energy), so sim results on either path are interchangeable.
#[test]
fn streaming_run_equals_trace_run() {
    let workload = WorkloadConfig::default()
        .with_requests(2_000)
        .with_arrivals(ArrivalProcess::Poisson { rate: 15.0 })
        .with_deadline_range(2.0, 6.0)
        .with_seed(11);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);

    let trace = generate(&workload);
    let mut s1 = CsUcb::with_defaults(cfg.n_servers());
    let r_trace = simulate(&cfg, &trace, &mut s1);

    let mut s2 = CsUcb::with_defaults(cfg.n_servers());
    let mut source = WorkloadGen::new(&workload);
    let r_stream = simulate_stream(&cfg, &mut source, &mut s2);

    assert_eq!(r_trace.outcomes.len(), r_stream.outcomes.len());
    assert_eq!(r_trace.events_processed, r_stream.events_processed);
    assert_eq!(r_trace.stale_events, r_stream.stale_events);
    assert_eq!(r_trace.dropped, r_stream.dropped);
    assert_eq!(r_trace.unfinished, r_stream.unfinished);
    assert!((r_trace.success_rate - r_stream.success_rate).abs() < 1e-12);
    assert!((r_trace.mean_processing_s - r_stream.mean_processing_s).abs() < 1e-12);
    assert!((r_trace.energy.total_j() - r_stream.energy.total_j()).abs() < 1e-9);
    for (a, b) in r_trace.outcomes.iter().zip(&r_stream.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.server, b.server);
        assert_eq!(a.tokens, b.tokens);
        assert!((a.completed_at - b.completed_at).abs() < 1e-12);
    }
}

/// A Simultaneous burst (all arrivals at t=0) still streams correctly:
/// the one-pending-arrival invariant handles equal-time arrivals in FIFO
/// order, exactly like the pre-pushed trace did.
#[test]
fn simultaneous_burst_streams_in_fifo_order() {
    let workload = WorkloadConfig::default()
        .with_requests(300)
        .with_arrivals(ArrivalProcess::Simultaneous)
        .with_seed(3);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);

    let trace = generate(&workload);
    let mut s1 = CsUcb::with_defaults(cfg.n_servers());
    let r_trace = simulate(&cfg, &trace, &mut s1);

    let mut s2 = CsUcb::with_defaults(cfg.n_servers());
    let mut source = WorkloadGen::new(&workload);
    let r_stream = simulate_stream(&cfg, &mut source, &mut s2);

    assert_eq!(r_trace.outcomes.len(), r_stream.outcomes.len());
    assert_eq!(r_trace.events_processed, r_stream.events_processed);
    assert!((r_trace.success_rate - r_stream.success_rate).abs() < 1e-12);
}
