//! Executable spec for the sharded parallel DES core (tentpole PR): the
//! sequential engine IS the specification, and the sharded engine must
//! reproduce it bit for bit at every shard count — outcomes, energy,
//! diagnostics, and availability accounting, with or without fault
//! plans. Perf counters (`events_processed`, `stale_events`,
//! `peak_event_queue_len`, wall time) are substrate-specific and
//! deliberately outside the identity surface.
//!
//! Contracts:
//!
//! 1. **Fault-free identity** — paper topology, both bandwidth modes
//!    (Fluctuating exercises the orchestrator's fluctuation-calendar
//!    replay of the engine RNG stream), shard plans {1, 2, auto,
//!    weighted}, multiple seeds, against a scheduler that exercises
//!    Assign, Defer,
//!    and Shed actions as well as CS-UCB.
//! 2. **Scaled-topology identity** — edgeshard-10x (60 servers, three
//!    tiers) under fluctuating bandwidth across shard counts.
//! 3. **Chaos identity** — crash (both `CrashPolicy` arms), degradation,
//!    link flap, leave/join churn, and a lagged health monitor: the
//!    merge barriers must replay incident accounting, crash teardown,
//!    and lagged-view publication exactly.
//! 4. **Bounded event population** — each engine's event queues stay
//!    bounded by in-flight concurrency: the sharded run's peak queue
//!    length never exceeds the sequential run's.
//! 5. **Any contiguous partition** — randomized split points and
//!    randomized volume-weighted plans (`ShardPlan::weighted`) reproduce
//!    the sequential run bit for bit, making the "correct for any
//!    contiguous partition" claim in `sim/shard.rs` executable.

use perllm::scheduler::csucb::CsUcb;
use perllm::scheduler::{Action, ClusterView, Scheduler, ShedReason};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::{
    simulate_stream, simulate_stream_faulted, simulate_stream_faulted_sharded,
    simulate_stream_sharded, RunReport,
};
use perllm::sim::{CrashPolicy, FaultKind, FaultPlan, HealthConfig, ShardCount, ShardPlan, TopologyConfig};
use perllm::util::proptest::{check, Gen};
use perllm::workload::generator::{ArrivalProcess, WorkloadConfig, WorkloadGen};
use perllm::workload::service::ServiceRequest;

/// Bit-level equality over the pinned identity surface. Stricter than
/// `faults_identity.rs`: every outcome float field, the full energy
/// breakdown, the diagnostics vector, and the availability report.
fn assert_reports_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: outcome order");
        assert_eq!(x.server, y.server, "{label}: placement of {}", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens of {}", x.id);
        for (fa, fb, what) in [
            (x.tx_time, y.tx_time, "tx_time"),
            (x.infer_time, y.infer_time, "infer_time"),
            (x.processing_time, y.processing_time, "processing_time"),
            (x.ttft_time, y.ttft_time, "ttft_time"),
            (x.energy_j, y.energy_j, "energy_j"),
            (x.completed_at, y.completed_at, "completed_at"),
        ] {
            assert_eq!(fa.to_bits(), fb.to_bits(), "{label}: {what} of {}", x.id);
        }
    }
    for (fa, fb, what) in [
        (a.energy.tran_j, b.energy.tran_j, "tran_j"),
        (a.energy.infer_j, b.energy.infer_j, "infer_j"),
        (a.energy.idle_j, b.energy.idle_j, "idle_j"),
        (a.makespan_s, b.makespan_s, "makespan"),
        (a.throughput_tok_s, b.throughput_tok_s, "throughput"),
        (a.success_rate, b.success_rate, "success_rate"),
        (a.mean_processing_s, b.mean_processing_s, "mean_processing"),
        (a.p95_processing_s, b.p95_processing_s, "p95_processing"),
        (
            a.energy_per_success_j,
            b.energy_per_success_j,
            "energy_per_success",
        ),
    ] {
        assert_eq!(fa.to_bits(), fb.to_bits(), "{label}: {what}");
    }
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(
        a.dropped_by_policy, b.dropped_by_policy,
        "{label}: dropped_by_policy"
    );
    assert_eq!(a.late, b.late, "{label}: late");
    assert_eq!(a.ttft_attainment, b.ttft_attainment, "{label}: ttft att");
    assert_eq!(
        a.completion_attainment, b.completion_attainment,
        "{label}: completion att"
    );
    assert_eq!(
        a.slo_ttft_violations, b.slo_ttft_violations,
        "{label}: ttft violations"
    );
    assert_eq!(
        a.slo_completion_violations, b.slo_completion_violations,
        "{label}: completion violations"
    );
    assert_eq!(
        a.slo_energy_violations, b.slo_energy_violations,
        "{label}: energy violations"
    );
    assert_eq!(a.gate_sheds, b.gate_sheds, "{label}: gate sheds");
    // Scheduler diagnostics are a pure function of the decision/feedback
    // stream, so any drift (including bandit statistics) surfaces here.
    assert_eq!(
        a.diagnostics.len(),
        b.diagnostics.len(),
        "{label}: diagnostics arity"
    );
    for ((ka, va), (kb, vb)) in a.diagnostics.iter().zip(&b.diagnostics) {
        assert_eq!(ka, kb, "{label}: diagnostics keys");
        assert_eq!(va.to_bits(), vb.to_bits(), "{label}: diagnostic {ka}");
    }
    match (&a.availability, &b.availability) {
        (None, None) => {}
        (Some(x), Some(y)) => {
            assert_eq!(x.incidents, y.incidents, "{label}: incidents");
            assert_eq!(
                x.incident_start_s.to_bits(),
                y.incident_start_s.to_bits(),
                "{label}: incident start"
            );
            assert_eq!(
                x.incident_end_s.to_bits(),
                y.incident_end_s.to_bits(),
                "{label}: incident end"
            );
            assert_eq!(
                x.failed_in_flight, y.failed_in_flight,
                "{label}: failed in flight"
            );
            assert_eq!(
                x.requeued_in_flight, y.requeued_in_flight,
                "{label}: requeued in flight"
            );
            assert_eq!(x.leaves, y.leaves, "{label}: leaves");
            assert_eq!(x.joins, y.joins, "{label}: joins");
            assert_eq!(x.attainment, y.attainment, "{label}: phase attainment");
            assert_eq!(
                x.time_to_recover_s.to_bits(),
                y.time_to_recover_s.to_bits(),
                "{label}: TTR"
            );
            assert_eq!(
                x.gate_sheds_by_phase, y.gate_sheds_by_phase,
                "{label}: gate sheds by phase"
            );
        }
        _ => panic!("{label}: availability presence differs"),
    }
}

fn workload(n: usize, rate: f64, seed: u64) -> WorkloadConfig {
    WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson { rate })
        .with_seed(seed)
}

/// Deterministic scheduler that exercises every action arm the
/// orchestrator must mirror: round-robin `Assign`, a periodic finite
/// `Defer` (stamped global Dispatch events), and a periodic `Shed`.
struct Mixed {
    n: usize,
    i: u64,
    fed: u64,
}

impl Scheduler for Mixed {
    fn name(&self) -> &'static str {
        "mixed-actions"
    }

    fn decide(&mut self, _req: &ServiceRequest, _view: &ClusterView) -> Action {
        self.i += 1;
        let server = (self.i as usize * 7) % self.n;
        if self.i % 41 == 0 {
            Action::Shed {
                reason: ShedReason::Overloaded,
            }
        } else if self.i % 5 == 0 {
            Action::Defer {
                server,
                delay_s: 0.05,
            }
        } else {
            Action::Assign { server }
        }
    }

    fn feedback(&mut self, _outcome: &perllm::workload::service::ServiceOutcome, view: &ClusterView) {
        // Consume the view epoch so any versioned-view divergence between
        // substrates changes a diagnostic, not just internal state.
        self.fed = self.fed.wrapping_add(view.epoch);
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        vec![
            ("mixed_decisions".into(), self.i as f64),
            ("mixed_epoch_sum".into(), self.fed as f64),
        ]
    }
}

fn mixed(n: usize) -> Mixed {
    Mixed { n, i: 0, fed: 0 }
}

/// Contract 1: fault-free identity on the paper topology across
/// bandwidth modes, shard counts, seeds, and schedulers.
#[test]
fn sharded_runs_are_bit_identical_to_sequential_on_paper_topology() {
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        for seed in [3u64, 17] {
            let topo = TopologyConfig::paper("llama2-7b", mode);
            let cfg = topo.build();
            let wl = workload(1200, 15.0, seed);
            let mut base_sched = CsUcb::with_defaults(cfg.n_servers());
            let mut base_src = WorkloadGen::new(&wl);
            let base = simulate_stream(&cfg, &mut base_src, &mut base_sched);
            for count in [
                ShardCount::Fixed(1),
                ShardCount::Fixed(2),
                ShardCount::Auto,
                ShardCount::Weighted(0),
            ] {
                let splan = topo.shard_plan(count);
                let mut sched = CsUcb::with_defaults(cfg.n_servers());
                let mut src = WorkloadGen::new(&wl);
                let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
                assert_reports_identical(
                    &base,
                    &got,
                    &format!("paper csucb {mode:?} seed={seed} shards={count:?}"),
                );
            }
            // The mixed-action scheduler (Defer + Shed paths).
            let mut base_sched = mixed(cfg.n_servers());
            let mut base_src = WorkloadGen::new(&wl);
            let base = simulate_stream(&cfg, &mut base_src, &mut base_sched);
            let splan = topo.shard_plan(ShardCount::Fixed(2));
            let mut sched = mixed(cfg.n_servers());
            let mut src = WorkloadGen::new(&wl);
            let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
            assert_reports_identical(
                &base,
                &got,
                &format!("paper mixed {mode:?} seed={seed}"),
            );
            assert!(base.dropped_by_policy > 0, "Shed arm exercised");
        }
    }
}

/// Contract 2: identity holds on the 10x three-tier fleet, where tier
/// boundaries give each shard a different lookahead window.
#[test]
fn sharded_runs_are_bit_identical_on_edgeshard_10x() {
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating);
    let cfg = topo.build();
    let wl = workload(2500, topo.scaled_rate(15.0), 29);
    let mut base_sched = CsUcb::with_defaults(cfg.n_servers());
    let mut base_src = WorkloadGen::new(&wl);
    let base = simulate_stream(&cfg, &mut base_src, &mut base_sched);
    for count in [
        ShardCount::Fixed(1),
        ShardCount::Fixed(4),
        ShardCount::Auto,
        ShardCount::Weighted(0),
        ShardCount::Weighted(4),
    ] {
        let splan = topo.shard_plan(count);
        let mut sched = CsUcb::with_defaults(cfg.n_servers());
        let mut src = WorkloadGen::new(&wl);
        let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
        assert_reports_identical(&base, &got, &format!("10x shards={count:?}"));
    }
}

/// Contract 3: chaos identity. Crash with mid-run recovery, permanent
/// crash, degradation, link flap, leave/join churn, lagged health
/// monitor — under both crash policies and several shard counts.
#[test]
fn sharded_runs_are_bit_identical_under_chaos() {
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating);
    let cfg = topo.build();
    let wl = workload(2200, topo.scaled_rate(15.0), 71);
    for policy in [CrashPolicy::Fail, CrashPolicy::Requeue] {
        let plan = FaultPlan::default()
            .with_event(
                20.0,
                FaultKind::Crash {
                    server: 3,
                    recover: Some(55.0),
                },
            )
            .with_event(
                25.0,
                FaultKind::Crash {
                    server: 50,
                    recover: None,
                },
            )
            .with_event(
                10.0,
                FaultKind::Degrade {
                    server: 49,
                    rate_factor: 0.4,
                    until: 60.0,
                },
            )
            .with_event(
                15.0,
                FaultKind::LinkFlap {
                    link: 2,
                    rate_factor: 0.2,
                    until: 45.0,
                },
            )
            .with_event(30.0, FaultKind::Leave { server: 10 })
            .with_event(70.0, FaultKind::Join { server: 10 })
            .with_health(HealthConfig {
                period_s: 1.0,
                lag_s: 5.0,
            })
            .with_crash_policy(policy);
        let mut base_sched = CsUcb::with_defaults(cfg.n_servers());
        let mut base_src = WorkloadGen::new(&wl);
        let base = simulate_stream_faulted(&cfg, &plan, &mut base_src, &mut base_sched);
        let av = base.availability.as_ref().expect("chaos run reports");
        assert!(av.incidents >= 2, "both crash windows fired");
        if policy == CrashPolicy::Requeue {
            assert!(av.requeued_in_flight > 0, "requeue path exercised");
        } else {
            assert!(av.failed_in_flight > 0, "fail path exercised");
        }
        for count in [ShardCount::Fixed(2), ShardCount::Auto, ShardCount::Weighted(3)] {
            let splan = topo.shard_plan(count);
            let mut sched = CsUcb::with_defaults(cfg.n_servers());
            let mut src = WorkloadGen::new(&wl);
            let got = simulate_stream_faulted_sharded(&cfg, &plan, &splan, &mut src, &mut sched);
            assert_reports_identical(
                &base,
                &got,
                &format!("chaos {policy:?} shards={count:?}"),
            );
        }
    }
}

/// Contract 4: per-queue event populations stay bounded. Every shard
/// queue holds a subset of the sequential queue's physics events and the
/// global calendar holds the (single) prefetched arrival + control
/// events, so the sharded peak can never exceed the sequential peak.
#[test]
fn sharded_event_population_is_bounded_by_the_sequential_one() {
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating);
    let cfg = topo.build();
    let wl = workload(1500, topo.scaled_rate(15.0), 5);
    let mut base_sched = CsUcb::with_defaults(cfg.n_servers());
    let mut base_src = WorkloadGen::new(&wl);
    let base = simulate_stream(&cfg, &mut base_src, &mut base_sched);
    for shards in [2usize, 3, 6] {
        let splan = ShardPlan::contiguous(cfg.n_servers(), shards);
        let mut sched = CsUcb::with_defaults(cfg.n_servers());
        let mut src = WorkloadGen::new(&wl);
        let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
        assert!(got.peak_event_queue_len > 0, "peak tracked");
        assert!(
            got.peak_event_queue_len <= base.peak_event_queue_len,
            "sharded peak {} exceeds sequential peak {} at {shards} shards",
            got.peak_event_queue_len,
            base.peak_event_queue_len
        );
        // Event conservation sanity: both substrates process the same
        // physics; the sharded total differs only by control/boundary
        // bookkeeping, so it stays within a small factor.
        assert!(got.events_processed > 0);
    }
}

/// Contract 5: randomized contiguous partitions — raw split points and
/// volume-weighted plans alike — all reproduce the sequential run bit
/// for bit on the three-tier 10x fleet. Every case also exercises the
/// active-feed lookahead derivation, because `run_sharded` derives each
/// shard's RTT classes from whatever ranges the plan produced.
#[test]
fn randomized_contiguous_partitions_are_bit_identical() {
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating);
    let cfg = topo.build();
    let wl = workload(800, topo.scaled_rate(15.0), 113);
    let mut base_sched = CsUcb::with_defaults(cfg.n_servers());
    let mut base_src = WorkloadGen::new(&wl);
    let base = simulate_stream(&cfg, &mut base_src, &mut base_sched);
    let n = cfg.n_servers();
    check("random contiguous partition identity", 10, |g: &mut Gen| {
        let splan = if g.bool() {
            // Random volume weights through the weighted splitter: the
            // plan changes, the report must not.
            let k = g.usize(1, 8);
            let weights: Vec<f64> = (0..n).map(|_| g.f64(0.0, 10.0)).collect();
            ShardPlan::weighted(n, &weights, k)
        } else {
            // Raw random split points, tier-oblivious on purpose —
            // single-server ranges and tier-straddling ranges included.
            let k = g.usize(1, 6);
            let mut cuts: Vec<usize> = (0..k.saturating_sub(1)).map(|_| g.usize(1, n - 1)).collect();
            cuts.sort_unstable();
            cuts.dedup();
            let mut ranges = Vec::new();
            let mut lo = 0usize;
            for c in cuts {
                ranges.push((lo, c));
                lo = c;
            }
            ranges.push((lo, n));
            ShardPlan { ranges }
        };
        let mut sched = CsUcb::with_defaults(cfg.n_servers());
        let mut src = WorkloadGen::new(&wl);
        let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
        assert_reports_identical(&base, &got, &format!("random plan {:?}", splan.ranges));
    });
}
