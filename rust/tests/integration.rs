//! Integration tests: scheduler × simulation engine × workload, including
//! the paper's headline comparisons at reduced scale and failure
//! injection (DESIGN.md §9).

use perllm::scheduler::csucb::CsUcb;
use perllm::scheduler::{
    agod::Agod, fineinfer::FineInfer, oracle::Oracle, rewardless::RewardlessGuidance,
};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig, Outage};
use perllm::sim::engine::simulate;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};

fn trace(n: usize, seed: u64) -> Vec<perllm::workload::service::ServiceRequest> {
    generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_deadline_range(2.0, 6.0)
            .with_seed(seed),
    )
}

/// The paper's core claim at test scale: CS-UCB beats every baseline on
/// success rate and throughput; ordering FineInfer < AGOD < Rewardless <
/// CS-UCB holds.
#[test]
fn paper_ordering_holds() {
    let t = trace(2000, 11);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);

    let mut fi = FineInfer::new(cfg.cloud_index());
    let mut agod = Agod::new(cfg.n_servers(), 11);
    let mut rg = RewardlessGuidance::new(cfg.n_servers());
    let mut cs = CsUcb::with_defaults(cfg.n_servers());

    let r_fi = simulate(&cfg, &t, &mut fi);
    let r_agod = simulate(&cfg, &t, &mut agod);
    let r_rg = simulate(&cfg, &t, &mut rg);
    let r_cs = simulate(&cfg, &t, &mut cs);

    assert!(
        r_cs.success_rate > r_rg.success_rate
            && r_rg.success_rate > r_agod.success_rate
            && r_agod.success_rate > r_fi.success_rate,
        "ordering broken: fi={:.2} agod={:.2} rg={:.2} cs={:.2}",
        r_fi.success_rate,
        r_agod.success_rate,
        r_rg.success_rate,
        r_cs.success_rate
    );
    assert!(r_cs.success_rate > 0.85, "cs-ucb too low: {}", r_cs.success_rate);
    assert!(
        r_cs.throughput_tok_s > 1.4 * r_fi.throughput_tok_s,
        "throughput gain too small: {} vs {}",
        r_cs.throughput_tok_s,
        r_fi.throughput_tok_s
    );
    // Energy per successful service: >40% below cloud-only.
    assert!(
        r_cs.energy_per_success_j < 0.6 * r_fi.energy_per_success_j,
        "energy win too small: {} vs {}",
        r_cs.energy_per_success_j,
        r_fi.energy_per_success_j
    );
}

/// CS-UCB approaches the clairvoyant oracle.
#[test]
fn csucb_near_oracle() {
    let t = trace(2000, 13);
    let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Stable);
    let mut cs = CsUcb::with_defaults(cfg.n_servers());
    let mut or = Oracle::new();
    let r_cs = simulate(&cfg, &t, &mut cs);
    let r_or = simulate(&cfg, &t, &mut or);
    assert!(
        r_cs.success_rate > r_or.success_rate - 0.08,
        "cs {} vs oracle {}",
        r_cs.success_rate,
        r_or.success_rate
    );
}

/// Regret grows sublinearly: per-decision regret shrinks between the first
/// and second half of the trace (Eq. 7's log growth, empirically).
#[test]
fn regret_sublinear_over_trace() {
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let t1 = trace(1500, 17);
    let mut cs = CsUcb::with_defaults(cfg.n_servers());
    let r1 = simulate(&cfg, &t1, &mut cs);
    let reg1: f64 = r1
        .diagnostics
        .iter()
        .find(|(k, _)| k == "cum_regret")
        .map(|(_, v)| *v)
        .unwrap();

    let t2 = trace(3000, 17);
    let mut cs2 = CsUcb::with_defaults(cfg.n_servers());
    let r2 = simulate(&cfg, &t2, &mut cs2);
    let reg2: f64 = r2
        .diagnostics
        .iter()
        .find(|(k, _)| k == "cum_regret")
        .map(|(_, v)| *v)
        .unwrap();

    // Doubling the horizon must far-less-than-double nothing — sublinear:
    // regret per decision shrinks.
    assert!(
        reg2 / 3000.0 <= reg1 / 1500.0 * 1.1,
        "per-decision regret grew: {reg1}/1500 -> {reg2}/3000"
    );
}

/// Failure injection: an edge server dies mid-trace; CS-UCB must route
/// around it without panicking and keep success above the all-edge-dead
/// floor.
#[test]
fn survives_server_outage() {
    let t = trace(1200, 19);
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable).with_outages(vec![
        Outage {
            server: 0,
            start: 10.0,
            end: 1.0e9,
        },
        Outage {
            server: 1,
            start: 20.0,
            end: 40.0,
        },
    ]);
    let mut cs = CsUcb::with_defaults(cfg.n_servers());
    let rep = simulate(&cfg, &t, &mut cs);
    assert_eq!(rep.outcomes.len(), 1200);
    assert!(
        rep.success_rate > 0.5,
        "collapsed under outage: {}",
        rep.success_rate
    );
}

/// Bandwidth collapse: fluctuating mode plus a burst arrival storm —
/// constraints still respected, no panics, every request resolved.
#[test]
fn survives_deadline_storm() {
    let t = generate(
        &WorkloadConfig::default()
            .with_requests(1500)
            .with_arrivals(ArrivalProcess::Bursty {
                base_rate: 5.0,
                burst_rate: 200.0,
                burst_len: 2.0,
                period: 15.0,
            })
            .with_deadline_range(2.0, 6.0)
            .with_seed(23),
    );
    let cfg = ClusterConfig::paper("yi-9b", BandwidthMode::Fluctuating);
    let mut cs = CsUcb::with_defaults(cfg.n_servers());
    let rep = simulate(&cfg, &t, &mut cs);
    assert_eq!(rep.outcomes.len(), 1500);
    // A 200-req/s burst is ~13x cluster capacity: most of each burst is
    // shed, but the system keeps serving between bursts instead of
    // collapsing entirely.
    assert!(rep.success_rate > 0.2, "{}", rep.success_rate);
    assert!(rep.unfinished == 0, "{} stuck requests", rep.unfinished);
}

/// Determinism across runs: identical seeds give identical reports.
#[test]
fn end_to_end_deterministic() {
    let t = trace(800, 29);
    let cfg = ClusterConfig::paper("llama3-8b", BandwidthMode::Fluctuating);
    let r1 = simulate(&cfg, &t, &mut CsUcb::with_defaults(cfg.n_servers()));
    let r2 = simulate(&cfg, &t, &mut CsUcb::with_defaults(cfg.n_servers()));
    assert_eq!(r1.outcomes.len(), r2.outcomes.len());
    assert!((r1.success_rate - r2.success_rate).abs() < 1e-12);
    assert!((r1.energy.total_j() - r2.energy.total_j()).abs() < 1e-6);
    assert!((r1.throughput_tok_s - r2.throughput_tok_s).abs() < 1e-9);
}

/// The fluctuating-bandwidth gap: baselines lose more success than CS-UCB
/// when links fluctuate (the paper's "advantage even more obvious" claim,
/// directionally).
#[test]
fn fluctuation_hurts_csucb_least() {
    let t = trace(2000, 31);
    let stable = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let fluct = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);

    let cs_s = simulate(&stable, &t, &mut CsUcb::with_defaults(6));
    let cs_f = simulate(&fluct, &t, &mut CsUcb::with_defaults(6));
    let drop_cs = cs_s.success_rate - cs_f.success_rate;
    assert!(drop_cs < 0.05, "cs-ucb lost {drop_cs} under fluctuation");
}
