//! Executable spec for the session subsystem (PR 10): multi-turn
//! conversations, KV-prefix reuse, and cache-affinity routing must be
//! strictly additive.
//!
//! Contracts:
//!
//! 1. **Scheduler identity off-sessions** — on a sessionless workload,
//!    `CsUcbAffinity` is bit-identical to `CsUcbSlo`: the stickiness
//!    bonus is branch-gated on `prefix_hit_tokens > 0`, so every
//!    decision, outcome float, and bandit diagnostic matches exactly.
//! 2. **Stream independence** — the session generator draws from
//!    `seed ^ SESSION_STREAM_SALT`, a side-stream of the workload seed:
//!    draining a `SessionSource` can never shift `WorkloadGen`'s
//!    sequence, and the two streams differ (the salt is real).
//! 3. **Substrate identity with sessions ON** — sequential and sharded
//!    runs of a sessioned workload agree bit for bit at every shard
//!    plan, *including* the prefix-cache counters (hits, prefill tokens
//!    saved, KV transfer bytes, evictions), which fold in global server
//!    order on both substrates.
//! 4. **Reuse is real** — a chat-heavy session run reports warm
//!    follow-up turns: nonzero hit rate, nonzero prefill tokens saved,
//!    per-class hits bounded by lookups, and hits concentrated in the
//!    chat class that dominates the mix.

use perllm::scheduler::csucb::{CsUcbAffinity, CsUcbSlo};
use perllm::sim::cluster::BandwidthMode;
use perllm::sim::engine::{simulate_stream, simulate_stream_sharded, RunReport};
use perllm::sim::{ShardCount, TopologyConfig};
use perllm::workload::generator::{ArrivalProcess, WorkloadConfig, WorkloadGen};
use perllm::workload::sessions::{SessionConfig, SessionSource};
use perllm::workload::service::ServiceClass;
use perllm::workload::ArrivalSource;

/// Bit-level equality over the identity surface, cache counters
/// included. Perf counters (`events_processed`, `stale_events`,
/// `peak_event_queue_len`, wall time, `shard_perf`) stay outside it —
/// same contract as `sharded_identity.rs`.
fn assert_reports_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: outcome order");
        assert_eq!(x.server, y.server, "{label}: placement of {}", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens of {}", x.id);
        for (fa, fb, what) in [
            (x.tx_time, y.tx_time, "tx_time"),
            (x.infer_time, y.infer_time, "infer_time"),
            (x.processing_time, y.processing_time, "processing_time"),
            (x.ttft_time, y.ttft_time, "ttft_time"),
            (x.energy_j, y.energy_j, "energy_j"),
            (x.completed_at, y.completed_at, "completed_at"),
        ] {
            assert_eq!(fa.to_bits(), fb.to_bits(), "{label}: {what} of {}", x.id);
        }
    }
    for (fa, fb, what) in [
        (a.energy.tran_j, b.energy.tran_j, "tran_j"),
        (a.energy.infer_j, b.energy.infer_j, "infer_j"),
        (a.energy.idle_j, b.energy.idle_j, "idle_j"),
        (a.makespan_s, b.makespan_s, "makespan"),
        (a.throughput_tok_s, b.throughput_tok_s, "throughput"),
        (a.success_rate, b.success_rate, "success_rate"),
        (a.mean_processing_s, b.mean_processing_s, "mean_processing"),
        (a.p95_processing_s, b.p95_processing_s, "p95_processing"),
    ] {
        assert_eq!(fa.to_bits(), fb.to_bits(), "{label}: {what}");
    }
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(a.late, b.late, "{label}: late");
    assert_eq!(
        a.slo_ttft_violations, b.slo_ttft_violations,
        "{label}: ttft violations"
    );
    assert_eq!(
        a.slo_completion_violations, b.slo_completion_violations,
        "{label}: completion violations"
    );
    // The session surface itself: every cache counter matches.
    assert_eq!(a.cache.lookups, b.cache.lookups, "{label}: cache lookups");
    assert_eq!(a.cache.hits, b.cache.hits, "{label}: cache hits");
    assert_eq!(
        a.cache.prefill_tokens_saved, b.cache.prefill_tokens_saved,
        "{label}: prefill saved"
    );
    assert_eq!(
        a.cache.kv_transfer_bytes, b.cache.kv_transfer_bytes,
        "{label}: kv transfer bytes"
    );
    assert_eq!(a.cache.evictions, b.cache.evictions, "{label}: evictions");
    assert_eq!(
        a.diagnostics.len(),
        b.diagnostics.len(),
        "{label}: diagnostics arity"
    );
    for ((ka, va), (kb, vb)) in a.diagnostics.iter().zip(&b.diagnostics) {
        assert_eq!(ka, kb, "{label}: diagnostics keys");
        assert_eq!(va.to_bits(), vb.to_bits(), "{label}: diagnostic {ka}");
    }
}

fn sessionless_workload(n: usize, seed: u64) -> WorkloadConfig {
    WorkloadConfig::default()
        .with_requests(n)
        .with_arrivals(ArrivalProcess::Poisson { rate: 15.0 })
        .with_seed(seed)
        .with_per_class_slos()
}

/// Chat-heavy sessioned workload: the mix PerLLM's "millions of users"
/// framing implies, and the one where prefix reuse should pay.
fn chat_heavy_sessions(n: usize, seed: u64, rate: f64) -> SessionConfig {
    SessionConfig::from_workload(
        WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate })
            .with_seed(seed)
            .with_per_class_slos()
            .with_class_weights([6.0, 1.0, 1.0, 2.0]),
    )
}

/// Contract 1: without sessions the affinity scheduler IS the SLO
/// scheduler — no view field it reads is ever nonzero, so the whole
/// report (outcomes, energy, bandit diagnostics) matches bit for bit.
#[test]
fn affinity_without_sessions_is_bit_identical_to_slo() {
    for seed in [11u64, 47] {
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let cfg = topo.build();
        let wl = sessionless_workload(1500, seed);
        let mut slo = CsUcbSlo::with_defaults(cfg.n_servers());
        let mut src = WorkloadGen::new(&wl);
        let base = simulate_stream(&cfg, &mut src, &mut slo);
        let mut aff = CsUcbAffinity::with_defaults(cfg.n_servers());
        let mut src = WorkloadGen::new(&wl);
        let got = simulate_stream(&cfg, &mut src, &mut aff);
        assert_reports_identical(&base, &got, &format!("affinity-off seed={seed}"));
        // A sessionless run never touches any cache.
        assert_eq!(base.cache.total_lookups(), 0);
        assert_eq!(got.cache.prefill_tokens_saved, 0);
        assert_eq!(got.cache.kv_transfer_bytes, 0);
    }
}

/// Contract 2: the session side-stream cannot perturb the single-turn
/// generator, and the salt genuinely decorrelates the two streams.
#[test]
fn session_stream_is_salted_and_leaves_base_stream_untouched() {
    let wl = WorkloadConfig::default().with_requests(300).with_seed(42);
    let drain = |src: &mut dyn ArrivalSource| {
        let mut out = Vec::new();
        while let Some(r) = src.next_arrival() {
            out.push(r);
        }
        out
    };
    let mut gen = WorkloadGen::new(&wl);
    let before = drain(&mut gen);
    // Interleave a full session generation between two base-stream runs.
    let sc = SessionConfig::from_workload(wl.clone());
    let mut sessions = SessionSource::new(&sc);
    let chained = drain(&mut sessions);
    let mut gen = WorkloadGen::new(&wl);
    let after = drain(&mut gen);
    assert_eq!(before.len(), after.len());
    for (x, y) in before.iter().zip(&after) {
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!(x.prompt_tokens, y.prompt_tokens);
        assert_eq!(x.output_tokens, y.output_tokens);
        assert!(x.session.is_none(), "base stream stays sessionless");
    }
    // Same seed, different stream: the salt must shift the arrivals.
    assert!(chained.iter().all(|r| r.session.is_some()));
    assert!(
        before
            .iter()
            .zip(&chained)
            .any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()),
        "session stream must not replay the base stream"
    );
}

/// Contract 3: sessions ride the versioned-view contract unchanged —
/// sharded runs (1 shard, 4 shards, volume-weighted) reproduce the
/// sequential sessioned run bit for bit, cache counters included.
#[test]
fn sessioned_runs_are_bit_identical_across_substrates() {
    let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Fluctuating);
    let cfg = topo.build();
    let sc = chat_heavy_sessions(1800, 23, topo.scaled_rate(15.0));
    let mut sched = CsUcbAffinity::with_defaults(cfg.n_servers());
    let mut src = SessionSource::new(&sc);
    let base = simulate_stream(&cfg, &mut src, &mut sched);
    assert!(
        base.cache.total_hits() > 0,
        "sessioned run must exercise the cache"
    );
    for count in [ShardCount::Fixed(1), ShardCount::Fixed(4), ShardCount::Weighted(0)] {
        let splan = topo.shard_plan(count);
        let mut sched = CsUcbAffinity::with_defaults(cfg.n_servers());
        let mut src = SessionSource::new(&sc);
        let got = simulate_stream_sharded(&cfg, &splan, &mut src, &mut sched);
        assert_reports_identical(&base, &got, &format!("sessions shards={count:?}"));
    }
}

/// Contract 4: reuse is real and sanely accounted on the paper fleet.
#[test]
fn warm_turns_save_prefill_with_consistent_counters() {
    let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Stable);
    let cfg = topo.build();
    let sc = chat_heavy_sessions(2500, 7, 15.0);
    let mut sched = CsUcbAffinity::with_defaults(cfg.n_servers());
    let mut src = SessionSource::new(&sc);
    let rep = simulate_stream(&cfg, &mut src, &mut sched);
    let cache = rep.cache;
    for c in 0..4 {
        assert!(
            cache.hits[c] <= cache.lookups[c],
            "class {c}: hits {} exceed lookups {}",
            cache.hits[c],
            cache.lookups[c]
        );
    }
    assert!(cache.total_lookups() > 0, "every admitted turn is a lookup");
    let hit_rate = cache.hit_rate().expect("lookups happened");
    assert!(
        hit_rate > 0.0,
        "chat-heavy sessions must find warm prefixes (rate {hit_rate})"
    );
    assert!(
        cache.prefill_tokens_saved > 0,
        "warm turns must skip prefill"
    );
    // The mix is chat-dominated, so reuse should be too.
    let chat = ServiceClass::Chat.index();
    assert!(
        cache.hits[chat] > 0,
        "chat drives the session mix, it must see hits"
    );
}
