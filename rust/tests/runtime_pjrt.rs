//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts` to have run (skips otherwise). This is the
//! proof that the three layers compose: Pallas kernel -> JAX model -> HLO
//! text -> PJRT CPU -> Rust tokens.

use perllm::runtime::{cpu_client, default_artifact_dir, Artifacts, ModelEngine};
use perllm::runtime::tokenizer::{argmax, decode, encode};

fn arts() -> Option<Artifacts> {
    Artifacts::discover(default_artifact_dir()).ok()
}

#[test]
fn edge_model_generates_coherent_text() {
    let Some(arts) = arts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = cpu_client().unwrap();
    let mut engine = ModelEngine::load(&client, &arts, "edge").unwrap();

    // The training corpus contains this phrase; a memorizing char-LM must
    // continue it sensibly under greedy decoding.
    let prompt = encode("Edge-cloud collab");
    let (logits, mut kv) = engine.prefill(&prompt).unwrap();
    assert_eq!(logits.len(), engine.meta.vocab);
    let mut tok = argmax(&logits);
    let mut out = vec![tok];
    let mut pos = prompt.len();
    for _ in 0..24 {
        let mut kvs = [&mut kv];
        let l = engine.decode_batch(&[tok], &[pos], &mut kvs).unwrap();
        tok = argmax(&l[0]);
        out.push(tok);
        pos += 1;
    }
    let text = decode(&out);
    eprintln!("edge continuation: {text:?}");
    // Memorized corpus: the continuation of "collab" is "oration ...".
    assert!(
        text.starts_with("oration"),
        "expected corpus continuation, got {text:?}"
    );
}

#[test]
fn batched_decode_matches_single_lane() {
    let Some(arts) = arts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = cpu_client().unwrap();
    let mut engine = ModelEngine::load(&client, &arts, "edge").unwrap();

    let p1 = encode("The cloud offers ");
    let p2 = encode("PerLLM schedules ");

    // Single-lane generation for each prompt.
    let gen_single = |engine: &mut ModelEngine, prompt: &[i32], steps: usize| -> Vec<i32> {
        let (logits, mut kv) = engine.prefill(prompt).unwrap();
        let mut tok = argmax(&logits);
        let mut out = vec![tok];
        let mut pos = prompt.len();
        for _ in 0..steps {
            let mut kvs = [&mut kv];
            let l = engine.decode_batch(&[tok], &[pos], &mut kvs).unwrap();
            tok = argmax(&l[0]);
            out.push(tok);
            pos += 1;
        }
        out
    };
    let solo1 = gen_single(&mut engine, &p1, 10);
    let solo2 = gen_single(&mut engine, &p2, 10);

    // Batched generation: both lanes together (bucket 2).
    let (l1, mut kv1) = engine.prefill(&p1).unwrap();
    let (l2, mut kv2) = engine.prefill(&p2).unwrap();
    let mut t1 = argmax(&l1);
    let mut t2 = argmax(&l2);
    let mut out1 = vec![t1];
    let mut out2 = vec![t2];
    let (mut pos1, mut pos2) = (p1.len(), p2.len());
    for _ in 0..10 {
        let mut kvs = [&mut kv1, &mut kv2];
        let l = engine
            .decode_batch(&[t1, t2], &[pos1, pos2], &mut kvs)
            .unwrap();
        t1 = argmax(&l[0]);
        t2 = argmax(&l[1]);
        out1.push(t1);
        out2.push(t2);
        pos1 += 1;
        pos2 += 1;
    }
    assert_eq!(solo1, out1, "lane 1 diverged under batching");
    assert_eq!(solo2, out2, "lane 2 diverged under batching");
}

#[test]
fn cloud_model_loads_and_generates() {
    let Some(arts) = arts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = cpu_client().unwrap();
    let mut engine = ModelEngine::load(&client, &arts, "cloud").unwrap();
    assert!(engine.meta.max_seq >= 128);
    let prompt = encode("The scheduler learns ");
    let (logits, mut kv) = engine.prefill(&prompt).unwrap();
    let mut tok = argmax(&logits);
    let mut pos = prompt.len();
    let mut out = vec![tok];
    for _ in 0..16 {
        let mut kvs = [&mut kv];
        let l = engine.decode_batch(&[tok], &[pos], &mut kvs).unwrap();
        tok = argmax(&l[0]);
        out.push(tok);
        pos += 1;
    }
    let text = decode(&out);
    eprintln!("cloud continuation: {text:?}");
    // All bytes must be printable ASCII from the training corpus.
    assert!(out.iter().all(|&t| (9..127).contains(&t)), "{text:?}");
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(arts) = arts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let client = cpu_client().unwrap();
    let mut engine = ModelEngine::load(&client, &arts, "edge").unwrap();
    assert!(engine.prefill(&[]).is_err());
    let too_long = vec![1i32; engine.meta.max_seq + 1];
    assert!(engine.prefill(&too_long).is_err());
    // Position past max_seq rejected.
    let mut kv = perllm::runtime::KvCache::zeroed(&engine.meta);
    let max = engine.meta.max_seq;
    let mut kvs = [&mut kv];
    assert!(engine.decode_batch(&[1], &[max], &mut kvs).is_err());
    // Oversized batch rejected.
    let b = engine.max_bucket() + 1;
    let toks = vec![1i32; b];
    let poss = vec![0usize; b];
    let mut kvv: Vec<perllm::runtime::KvCache> =
        (0..b).map(|_| perllm::runtime::KvCache::zeroed(&engine.meta)).collect();
    let mut refs: Vec<&mut perllm::runtime::KvCache> = kvv.iter_mut().collect();
    assert!(engine.decode_batch(&toks, &poss, &mut refs).is_err());
}
