//! Proof that the live router's decision path is allocation-free: a
//! counting global allocator observes zero heap allocations across warmed
//! `route()`/`complete()` cycles. This is the serving-path twin of the
//! DES engine's scratch-view discipline (PR 1) — the pre-Action router
//! collected a fresh `ClusterView` on every route *and* complete.
//!
//! Lives in its own integration-test binary because `#[global_allocator]`
//! is per-binary, and this file holds exactly one test so no other test
//! thread can allocate concurrently with the measured section.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use perllm::coordinator::router::{Router, WorkerTelemetry};
use perllm::scheduler::csucb::CsUcb;
use perllm::sim::server::ServerKind;
use perllm::workload::service::{ServiceClass, ServiceOutcome, SloSpec};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn route_and_complete_do_not_allocate_once_warm() {
    let workers = vec![
        Arc::new(WorkerTelemetry::new(ServerKind::Edge, 4, 8)),
        Arc::new(WorkerTelemetry::new(ServerKind::Edge, 4, 8)),
        Arc::new(WorkerTelemetry::new(ServerKind::Cloud, 8, 16)),
    ];
    let mut router = Router::new(Box::new(CsUcb::with_defaults(3)), workers);
    let req = Router::service_request(5, ServiceClass::Chat, 32, 32, 10.0);

    let complete_for = |worker: usize| ServiceOutcome {
        id: 5,
        class: ServiceClass::Chat,
        server: worker,
        tx_time: 0.0,
        infer_time: 0.1,
        processing_time: 0.1,
        ttft_time: 0.05,
        slo: SloSpec::completion_only(10.0),
        energy_j: 30.0,
        tokens: 64,
        completed_at: 0.0,
    };

    // Warm-up: grow the scratch view, the CS-UCB arm table access paths,
    // and the pending-penalty dense vec to steady state.
    for _ in 0..64 {
        let w = router.route(&req).worker().expect("placed");
        router.complete(&complete_for(w));
    }

    // Let any allocator bookkeeping from the warm-up settle, then measure.
    std::thread::sleep(Duration::from_millis(10));
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for _ in 0..1_000 {
        let w = router.route(&req).worker().expect("placed");
        router.complete(&complete_for(w));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "router decision path allocated {} times over 1000 warmed route+complete cycles",
        after - before
    );
}
