//! Integration tests for the token-batch service model: the fluid-limit
//! differential against the PS queue, end-to-end solo reduction, and the
//! honest-predictor regression on both models (acceptance criteria of
//! the `ServiceModel` refactor).

use perllm::scheduler::{Action, ClusterView, Scheduler};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::energy::EnergyWeights;
use perllm::sim::engine::simulate;
use perllm::sim::net::LinkSpec;
use perllm::sim::server::{ServerKind, ServerSpec};
use perllm::sim::service_model::ServiceModelKind;
use perllm::sim::topology::TopologyConfig;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};
use perllm::workload::service::ServiceRequest;

/// Fixed-target scheduler that records the decision-time view of its
/// target (predicted completion + TTFT).
struct Capture {
    target: usize,
    predicted: Vec<(f64, f64)>,
}

impl Capture {
    fn new(target: usize) -> Self {
        Capture {
            target,
            predicted: Vec::new(),
        }
    }
}

impl Scheduler for Capture {
    fn name(&self) -> &'static str {
        "capture"
    }
    fn decide(&mut self, _r: &ServiceRequest, v: &ClusterView) -> Action {
        let sv = &v.servers[self.target];
        self.predicted.push((sv.predicted_time, sv.predicted_ttft));
        Action::assign(self.target)
    }
}

/// One server behind one edge link; `slots`/`alpha` parameterized so the
/// fluid limit (slots = 1, linear curve) is constructible.
fn single_server_cfg(model: ServiceModelKind, slots: usize, alpha: f64) -> ClusterConfig {
    ClusterConfig {
        servers: vec![ServerSpec {
            name: "solo".into(),
            kind: ServerKind::Edge,
            prefill_rate: 1550.0,
            decode_rate: 51.0,
            slots,
            batch_alpha: alpha,
            p_infer: 45.0,
            p_idle: 6.0,
            compute_capacity: 8.0,
            queue_limit: 64,
            service_model: model,
        }],
        links: vec![LinkSpec::edge(0, false)],
        bandwidth: BandwidthMode::Stable,
        weights: EnergyWeights::default(),
        outages: Vec::new(),
        seed: 1,
        churn_guard: true,
    }
}

fn light_trace(n: usize, rate: f64, seed: u64) -> Vec<ServiceRequest> {
    generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate })
            .with_deadline_range(20.0, 40.0) // generous: physics, not SLOs
            .with_seed(seed),
    )
}

/// Fluid-limit differential: at batch = 1 with a linear efficiency curve
/// both models are FIFO servers at the solo rate; the only divergence the
/// token-batch model may show is its whole-iteration quantization (at
/// most one iteration per completed service, accumulated through the
/// FIFO queue). Checked per outcome against the PS run.
#[test]
fn fluid_limit_matches_ps_within_iteration_quantization() {
    let trace = light_trace(60, 0.8, 5);
    let cfg_ps = single_server_cfg(ServiceModelKind::Ps, 1, 1.0);
    let cfg_tb = single_server_cfg(
        ServiceModelKind::TokenBatch { kv_tokens: 1536 },
        1,
        1.0,
    );
    let r_ps = simulate(&cfg_ps, &trace, &mut Capture::new(0));
    let r_tb = simulate(&cfg_tb, &trace, &mut Capture::new(0));
    assert_eq!(r_ps.outcomes.len(), r_tb.outcomes.len());
    assert_eq!(r_ps.unfinished, 0);
    assert_eq!(r_tb.unfinished, 0);
    assert_eq!(r_ps.dropped, 0);
    assert_eq!(r_tb.dropped, 0);
    let d1 = 1.0 / 51.0; // one solo iteration
    for (i, (a, b)) in r_ps.outcomes.iter().zip(&r_tb.outcomes).enumerate() {
        assert_eq!(a.id, b.id, "completion order diverged at {i}");
        // Quantization only rounds service *up*…
        assert!(
            b.processing_time + 1e-9 >= a.processing_time,
            "token-batch finished {} early: {} vs {}",
            a.id,
            b.processing_time,
            a.processing_time
        );
        // …by at most one iteration per service completed so far (FIFO
        // queue accumulates the rounding).
        let bound = (i + 1) as f64 * d1 + 1e-6;
        assert!(
            b.processing_time - a.processing_time <= bound,
            "fluid limit diverged at {}: {} vs {} (bound {bound})",
            a.id,
            b.processing_time,
            a.processing_time
        );
    }
}

/// End-to-end solo reduction: one request through the full engine on a
/// token-batch server spends exactly its quantized prefill + decode time
/// in service.
#[test]
fn single_request_reduces_to_solo_prefill_plus_decode() {
    let cfg = single_server_cfg(ServiceModelKind::TokenBatch { kv_tokens: 1536 }, 8, 0.58);
    let trace = light_trace(1, 1.0, 9);
    let rep = simulate(&cfg, &trace, &mut Capture::new(0));
    assert_eq!(rep.outcomes.len(), 1);
    let o = &rep.outcomes[0];
    assert!(o.success(), "uncontended request must succeed");
    let r = &trace[0];
    let solo = r.prompt_tokens as f64 / 1550.0 + r.output_tokens as f64 / 51.0;
    let d1 = 1.0 / 51.0;
    assert!(
        o.infer_time >= solo - 1e-9 && o.infer_time <= solo + d1 + 1e-9,
        "infer {} vs solo {solo} (+ at most one iteration {d1})",
        o.infer_time
    );
}

/// Honest-predictor regression, both models: on an uncontended server the
/// decision-time `predicted_time` must equal the realized processing
/// time, and `predicted_ttft` must be a positive estimate below it. (The
/// PS predictor was already exact here; the token-batch predictor uses
/// the same whole-iteration schedule as its completions, so it is exact
/// too — not a fluid approximation of itself.)
#[test]
fn uncontended_predictions_match_realized_time_on_both_models() {
    for (label, model) in [
        ("ps", ServiceModelKind::Ps),
        ("token-batch", ServiceModelKind::TokenBatch { kv_tokens: 1536 }),
    ] {
        let cfg = single_server_cfg(model, 8, 0.58);
        // Arrivals pinned 50 s apart: each request finds the server idle
        // and fully drained (no Poisson luck involved).
        let mut trace = light_trace(5, 1.0, 23);
        for (i, r) in trace.iter_mut().enumerate() {
            r.arrival = i as f64 * 50.0;
        }
        let mut sched = Capture::new(0);
        let rep = simulate(&cfg, &trace, &mut sched);
        assert_eq!(rep.outcomes.len(), 5, "{label}");
        assert_eq!(rep.unfinished + rep.dropped, 0, "{label}");
        for (o, &(predicted, ttft)) in rep.outcomes.iter().zip(&sched.predicted) {
            assert!(
                (o.processing_time - predicted).abs() <= 1e-6 * predicted.max(1.0),
                "{label}: request {} realized {} vs predicted {predicted}",
                o.id,
                o.processing_time
            );
            assert!(ttft > 0.0 && ttft <= predicted + 1e-12, "{label}: ttft {ttft}");
        }
    }
}

/// The paper topology fully on token-batch servers completes a paper-rate
/// workload end to end with every scheduler-facing layer intact
/// (feasibility filters, candidate pruning, feedback views).
#[test]
fn token_batch_paper_topology_completes_paper_rate_load() {
    use perllm::scheduler::csucb::CsUcb;
    let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Stable)
        .with_service_model_by_name("token-batch")
        .expect("known model");
    let cfg = topo.build();
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(600)
            .with_arrivals(ArrivalProcess::Poisson { rate: 12.0 })
            .with_deadline_range(2.0, 6.0)
            .with_seed(31),
    );
    let mut s = CsUcb::with_defaults(cfg.n_servers());
    let rep = simulate(&cfg, &trace, &mut s);
    assert_eq!(rep.outcomes.len(), 600);
    assert_eq!(rep.unfinished, 0, "token-batch servers must drain");
    assert!(rep.success_rate > 0.5, "success {}", rep.success_rate);
    assert!(rep.energy.total_j() > 0.0);
    // Iteration-granular completions still play by the DES accounting
    // rules: bounded heap, sane stale ratio.
    assert!(rep.stale_ratio < 1.0);
    assert!(rep.peak_event_queue_len < 600);
}
