//! End-to-end serving test over the REAL AOT artifacts: router + batcher +
//! KV pool + PJRT engines, all three layers on the request path.
//! Skips gracefully if `make artifacts` hasn't run.

use std::time::Duration;

use perllm::coordinator::server::{ServeRequest, ServingCluster};
use perllm::runtime::{cpu_client, default_artifact_dir, Artifacts, ModelEngine};
use perllm::scheduler::csucb::CsUcb;
use perllm::sim::server::ServerKind;
use perllm::workload::service::ServiceClass;

fn have_artifacts() -> bool {
    Artifacts::discover(default_artifact_dir()).is_ok()
}

fn real_cluster(edge_workers: usize) -> ServingCluster {
    type Factory = Box<dyn FnOnce() -> anyhow::Result<ModelEngine> + Send>;
    let dir = default_artifact_dir();
    let mut engines: Vec<(ServerKind, Factory)> = Vec::new();
    for _ in 0..edge_workers {
        let d = dir.clone();
        engines.push((
            ServerKind::Edge,
            Box::new(move || ModelEngine::load(&cpu_client()?, &Artifacts::discover(&d)?, "edge")),
        ));
    }
    let d = dir.clone();
    engines.push((
        ServerKind::Cloud,
        Box::new(move || ModelEngine::load(&cpu_client()?, &Artifacts::discover(&d)?, "cloud")),
    ));
    let n = engines.len();
    ServingCluster::start(engines, Box::new(CsUcb::with_defaults(n)), 7).unwrap()
}

#[test]
fn serves_real_models_through_the_full_stack() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cluster = real_cluster(1);
    let n = 6;
    for i in 0..n {
        cluster
            .submit(ServeRequest {
                id: i,
                prompt: "Edge-cloud collab".into(),
                max_new_tokens: 12,
                deadline_s: 120.0,
                ttft_slo_s: None,
                class: ServiceClass::Chat,
                temperature: 0.0,
                top_k: 1,
            })
            .unwrap();
    }
    let mut replies = Vec::new();
    while replies.len() < n as usize {
        let r = cluster
            .recv_completion(Duration::from_secs(180))
            .expect("completion before timeout");
        replies.push(r);
    }
    cluster.shutdown();

    for r in &replies {
        assert_eq!(r.tokens, 12, "wrong generation length");
        // The trained edge model memorized the corpus: greedy continuation
        // of "collab" must start with "oration". The cloud model was
        // trained on the same corpus, so both workers agree here.
        assert!(
            r.text.starts_with("oration"),
            "unexpected continuation {:?} from worker {}",
            r.text,
            r.worker
        );
    }
    // Identical greedy requests -> identical text from every worker.
    let first = &replies[0].text;
    assert!(replies.iter().all(|r| &r.text == first));
}

#[test]
fn mixed_workload_all_complete_and_metrics_consistent() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cluster = real_cluster(2);
    let prompts = [
        "The cloud offers ",
        "PerLLM schedules ",
        "Diverse services ",
        "The scheduler learns ",
    ];
    let n = 12u64;
    for i in 0..n {
        cluster
            .submit(ServeRequest {
                id: i,
                prompt: prompts[i as usize % prompts.len()].into(),
                max_new_tokens: 8 + (i as usize % 3) * 4,
                deadline_s: 300.0,
                // Interactive classes carry a (loose) TTFT bound through
                // the full stack; batch classes stay completion-only.
                ttft_slo_s: ServiceClass::ALL[i as usize % 4].default_ttft().map(|_| 150.0),
                class: ServiceClass::ALL[i as usize % 4],
                temperature: 0.8,
                top_k: 200,
            })
            .unwrap();
    }
    let mut total_tokens = 0u64;
    let mut seen = std::collections::HashSet::new();
    for _ in 0..n {
        let r = cluster
            .recv_completion(Duration::from_secs(180))
            .expect("completion");
        assert!(seen.insert(r.id), "duplicate completion {}", r.id);
        assert!(r.tokens > 0);
        total_tokens += r.tokens;
    }
    assert_eq!(seen.len(), n as usize);
    // Metrics agree with what we observed.
    assert_eq!(
        cluster
            .metrics
            .tokens_out
            .load(std::sync::atomic::Ordering::Relaxed),
        total_tokens
    );
    assert_eq!(
        cluster
            .metrics
            .requests_done
            .load(std::sync::atomic::Ordering::Relaxed),
        n
    );
    cluster.shutdown();
}
