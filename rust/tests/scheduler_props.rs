//! Property-based tests on scheduler and simulation invariants
//! (DESIGN.md §9), using the crate's own proptest harness.

use perllm::scheduler::csucb::{CsUcb, CsUcbParams};
use perllm::scheduler::{Action, ClusterView, Scheduler, ServerView};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::energy::EnergyWeights;
use perllm::sim::engine::simulate;
use perllm::sim::ps::PsQueue;
use perllm::sim::server::ServerKind;
use perllm::util::proptest::{check, Gen};
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};
use perllm::workload::service::{ServiceClass, ServiceRequest, SloSpec};

fn random_view(g: &mut Gen, n: usize) -> ClusterView {
    let servers = (0..n)
        .map(|i| {
            let cap = g.f64(0.5, 20.0);
            ServerView {
                kind: if i == n - 1 {
                    ServerKind::Cloud
                } else {
                    ServerKind::Edge
                },
                predicted_time: g.f64(0.1, 12.0),
                predicted_ttft: g.f64(0.05, 6.0),
                compute_headroom: cap,
                compute_demand: g.f64(0.0, 25.0),
                bandwidth_headroom: g.f64(1.0e5, 3.0e8),
                bandwidth_demand: g.f64(1.0e4, 1.0e9),
                tx_energy_est: g.f64(0.1, 20.0),
                infer_energy_est: g.f64(1.0, 200.0),
                n_active: g.usize(0, 16),
                n_waiting: g.usize(0, 16),
                solo_time_est: g.f64(0.1, 5.0),
                occupancy: g.f64(0.0, 1.0),
                observed_health: 1.0,
            }
        })
        .collect();
    ClusterView {
        now: 0.0,
        epoch: 0,
        servers,
        weights: EnergyWeights::default(),
        candidates: Vec::new(),
    }
}

/// Random SLO contract covering every variant: completion-only (the
/// paper's scalar), TTFT-only, both, with and without an energy budget.
fn random_slo(g: &mut Gen) -> SloSpec {
    let ttft = g.bool().then(|| g.f64(0.05, 4.0));
    // Keep at least one timing constraint present: all-absent contracts
    // are legal but vacuous (everything trivially feasible).
    let completion = if ttft.is_some() && g.bool() {
        None
    } else {
        Some(g.f64(0.5, 8.0))
    };
    SloSpec {
        ttft,
        completion,
        energy_budget_j: g.bool().then(|| g.f64(1.0, 300.0)),
    }
}

fn random_req(g: &mut Gen) -> ServiceRequest {
    req_with_slo(g, SloSpec::completion_only(g.f64(0.5, 8.0)))
}

fn req_with_slo(g: &mut Gen, slo: SloSpec) -> ServiceRequest {
    ServiceRequest {
        id: g.u64(0, 1 << 40),
        class: *g.pick(&ServiceClass::ALL),
        arrival: 0.0,
        prompt_tokens: g.usize(1, 1024) as u32,
        output_tokens: g.usize(1, 512) as u32,
        slo,
        payload_bytes: g.u64(1_000, 5_000_000),
        session: None,
    }
}

#[test]
fn prop_constraint_filter_soundness() {
    // f(y) >= 0 implies every individual *present* constraint holds
    // (Eq. 3, generalized to the SLO vector).
    check("f(y) soundness", 300, |g| {
        let n = g.usize(1, 8);
        let view = random_view(g, n);
        let slo = random_slo(g);
        let req = req_with_slo(g, slo);
        for j in view.feasible_servers(&req) {
            let sv = &view.servers[j];
            if let Some(d) = req.slo.completion {
                assert!(sv.predicted_time <= d + 1e-9, "C1 completion violated");
            }
            if let Some(t) = req.slo.ttft {
                assert!(sv.predicted_ttft <= t + 1e-9, "C1 TTFT violated");
            }
            if let Some(b) = req.slo.energy_budget_j {
                assert!(
                    sv.tx_energy_est + sv.infer_energy_est <= b + 1e-9,
                    "energy budget violated"
                );
            }
            assert!(sv.compute_demand <= sv.compute_headroom + 1e-9, "C2 violated");
            assert!(
                sv.bandwidth_demand <= sv.bandwidth_headroom + 1e-9,
                "C3 violated"
            );
        }
    });
}

/// The `_into` feasibility helpers must equal a brute-force scan of
/// `constraint_satisfaction` over every server — under every SLO variant
/// (completion-only, TTFT-only, both, energy budget) and under candidate
/// pruning that honors the source's invariant (pruned ⇒ zero compute
/// headroom ⇒ provably infeasible).
#[test]
fn prop_feasible_set_equals_full_scan_under_slo_variants() {
    check("feasible ≡ full scan (SLO)", 400, |g| {
        let n = g.usize(1, 8);
        let mut view = random_view(g, n);
        // Emulate the ClusterSim admissibility index: some servers
        // saturated (zero headroom), the candidate list naming the rest.
        if g.bool() {
            let mut candidates = Vec::new();
            for j in 0..n {
                if g.bool() {
                    view.servers[j].compute_headroom = 0.0;
                } else {
                    candidates.push(j as u32);
                }
            }
            // Empty list is the "no pruning info" sentinel — only export
            // the index when it actually excludes someone (the source
            // does the same).
            if candidates.len() < n {
                view.candidates = candidates;
            }
        }
        let slo = random_slo(g);
        let req = req_with_slo(g, slo);
        let margin = g.f64(0.0, 0.5);
        let brute: Vec<usize> = (0..n)
            .filter(|&j| view.constraint_satisfaction(&req, j) >= margin)
            .collect();
        let mut buf = vec![usize::MAX; g.usize(0, 12)];
        view.feasible_servers_with_slack_into(&req, margin, &mut buf);
        assert_eq!(buf, brute, "pruned scan diverged from brute force");
        if margin == 0.0 {
            assert_eq!(view.feasible_servers(&req), brute);
        }
    });
}

#[test]
fn prop_csucb_picks_feasible_when_any_exists() {
    // Plain CS-UCB filters through the completion-only lens; CsUcbSlo
    // through the full vector. Each must stay inside its own feasible
    // set whenever that set is non-empty.
    use perllm::scheduler::csucb::CsUcbSlo;
    check("cs-ucb feasibility", 300, |g| {
        let n = g.usize(2, 8);
        let view = random_view(g, n);
        let slo = random_slo(g);
        let req = req_with_slo(g, slo);
        let completion_feasible: Vec<usize> = (0..n)
            .filter(|&j| view.completion_satisfaction(&req, j) >= 0.0)
            .collect();
        let vector_feasible = view.feasible_servers(&req);
        let mut plain = CsUcb::with_defaults(n);
        let mut slo = CsUcbSlo::with_defaults(n);
        for (name, action, feasible) in [
            ("cs-ucb", plain.decide(&req, &view), &completion_feasible),
            ("cs-ucb-slo", slo.decide(&req, &view), &vector_feasible),
        ] {
            match action {
                Action::Assign { server } => {
                    assert!(server < n, "{name} out of range");
                    if !feasible.is_empty() {
                        assert!(
                            feasible.contains(&server),
                            "{name} picked infeasible {server} with feasible {feasible:?}"
                        );
                    }
                }
                Action::Shed { .. } => {
                    // Shedding is only legal when nothing is feasible
                    // (deep violation everywhere).
                    assert!(feasible.is_empty(), "{name} shed despite {feasible:?}");
                }
                Action::Defer { .. } => panic!("{name} never defers"),
            }
        }
    });
}

#[test]
fn prop_feasible_into_matches_allocating_form() {
    // The scratch-buffer `_into` helpers and the Vec-returning wrappers
    // must agree for any view, request, and margin — including with stale
    // buffer content from a previous (larger) fill.
    check("feasible _into equivalence", 300, |g| {
        let n = g.usize(1, 8);
        let view = random_view(g, n);
        let req = random_req(g);
        let margin = g.f64(-0.5, 0.5);
        let mut buf = vec![usize::MAX; g.usize(0, 12)];
        view.feasible_servers_into(&req, &mut buf);
        assert_eq!(buf, view.feasible_servers(&req));
        view.feasible_servers_with_slack_into(&req, margin, &mut buf);
        assert_eq!(buf, view.feasible_servers_with_slack(&req, margin));
    });
}

#[test]
fn prop_least_violating_is_argmax_fy() {
    check("least violating", 200, |g| {
        let n = g.usize(1, 8);
        let view = random_view(g, n);
        let req = random_req(g);
        let j = view.least_violating(&req);
        let fj = view.constraint_satisfaction(&req, j);
        for k in 0..n {
            assert!(view.constraint_satisfaction(&req, k) <= fj + 1e-12);
        }
    });
}

#[test]
fn prop_every_request_gets_exactly_one_outcome() {
    // C4 single-assignment + engine conservation: every request in the
    // trace yields exactly one outcome, whatever the load level.
    check("outcome conservation", 12, |g| {
        let n = g.usize(20, 300);
        let rate = g.f64(2.0, 60.0);
        let seed = g.u64(0, 1 << 32);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_arrivals(ArrivalProcess::Poisson { rate })
                .with_seed(seed),
        );
        let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Fluctuating);
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert_eq!(rep.outcomes.len(), n);
        let mut ids: Vec<u64> = rep.outcomes.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate or missing outcomes");
    });
}

#[test]
fn prop_energy_non_negative_and_consistent() {
    check("energy consistency", 8, |g| {
        let n = g.usize(30, 200);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_seed(g.u64(0, 999)),
        );
        let cfg = ClusterConfig::paper("llama3-8b", BandwidthMode::Stable);
        let mut s = CsUcb::with_defaults(cfg.n_servers());
        let rep = simulate(&cfg, &trace, &mut s);
        assert!(rep.energy.tran_j >= 0.0);
        assert!(rep.energy.infer_j >= 0.0);
        assert!(rep.energy.idle_j >= 0.0);
        // Per-service attributed energy never exceeds the cluster total.
        let attributed: f64 = rep.outcomes.iter().map(|o| o.energy_j).sum();
        assert!(
            attributed <= rep.energy.total_j() + 1e-6,
            "attributed {attributed} > total {}",
            rep.energy.total_j()
        );
    });
}

#[test]
fn prop_ps_queue_work_conserved_and_bounded() {
    check("ps conservation", 200, |g| {
        let mut q = PsQueue::new(g.usize(1, 8));
        let mut pushed = 0.0f64;
        let mut id = 0u64;
        let mut now = 0.0f64;
        for _ in 0..g.usize(1, 60) {
            if g.bool() {
                let w = g.f64(0.1, 5.0);
                pushed += w;
                q.push(id, w, now);
                id += 1;
            } else {
                let rate = g.f64(0.1, 3.0);
                let dt = g.f64(0.0, 2.0);
                // Cap dt at the next completion so jobs don't go negative.
                let dt = match q.next_completion_in(rate) {
                    Some(eta) => dt.min(eta),
                    None => dt,
                };
                q.advance(dt, rate);
                now += dt;
                let _ = q.reap(now, rate);
            }
        }
        let remaining = q.backlog();
        assert!(remaining >= -1e-6);
        assert!(remaining <= pushed + 1e-6, "backlog exceeds pushed work");
    });
}

#[test]
fn prop_ucb_reward_monotone_in_energy() {
    // Lower energy at the same timing outcome => weakly higher reward, and
    // success beats failure at equal energy (Eq. 4 sanity).
    check("reward monotonicity", 200, |g| {
        let p = CsUcbParams::default();
        let mk = |energy: f64, proc: f64, deadline: f64| perllm::workload::service::ServiceOutcome {
            id: 0,
            class: ServiceClass::Chat,
            server: 0,
            tx_time: 0.1,
            infer_time: proc,
            processing_time: proc,
            ttft_time: 0.1,
            slo: SloSpec::completion_only(deadline),
            energy_j: energy,
            tokens: 10,
            completed_at: proc,
        };
        let d = g.f64(1.0, 8.0);
        let proc = g.f64(0.1, 10.0);
        let e1 = g.f64(0.0, 5000.0);
        let e2 = e1 + g.f64(0.0, 5000.0);
        let r1 = CsUcb::reward(&p, &mk(e1, proc, d));
        let r2 = CsUcb::reward(&p, &mk(e2, proc, d));
        assert!(r1 >= r2 - 1e-12, "reward not monotone: {r1} < {r2}");
        let ok = CsUcb::reward(&p, &mk(e1, d * 0.5, d));
        let late = CsUcb::reward(&p, &mk(e1, d * 2.0, d));
        assert!(ok > late);
    });
}

#[test]
fn prop_workload_generation_valid() {
    check("workload validity", 60, |g| {
        let cfg = WorkloadConfig::default()
            .with_requests(g.usize(1, 500))
            .with_deadline_range(2.0, 6.0)
            .with_seed(g.u64(0, 1 << 30));
        for r in generate(&cfg) {
            assert!(r.prompt_tokens >= 1);
            assert!(r.output_tokens >= 1);
            let completion = r.slo.completion.expect("generated workloads carry a completion bound");
            assert!((2.0..=6.0).contains(&completion));
            assert!(r.payload_bytes > 0);
            assert!(r.arrival >= 0.0);
        }
    });
}
