//! Run-identity pins for the SLO-contract redesign (PR 5), in the style
//! of `service_model_identity.rs`: executable specifications of the
//! pre-PR5 behavior run against the production code, bit for bit.
//!
//! Three contracts are pinned:
//!
//! 1. **Scalar-lens identity** — `ReferenceScalarCsUcb` below is the
//!    pre-PR5 CS-UCB decision/feedback logic, copied formula for formula
//!    (the scalar `(D∆ - predicted) / D∆` C1 term, the fused UCB loop,
//!    the first-max fallback, the Eq.-4 reward on completion slack). On
//!    completion-only workloads the production `CsUcb::with_defaults`
//!    must reproduce it outcome for outcome, to the bit.
//! 2. **Vector degeneration** — `CsUcbSlo` (the full SLO-vector lens) is
//!    decision-identical to `CsUcb` when every contract is
//!    completion-only: the vector min_slack collapses to the scalar C1
//!    float exactly.
//! 3. **Workload-mode isolation** — switching the generator to per-class
//!    SLO sampling must not move a single arrival, token draw, or
//!    completion instant (the SLO side-stream is independent); only the
//!    contract fields and the attainment accounting may change.

use perllm::scheduler::csucb::{CsUcb, CsUcbParams, CsUcbSlo};
use perllm::scheduler::{Action, ClusterView, Scheduler, ShedReason};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::{simulate, RunReport};
use perllm::sim::topology::TopologyConfig;
use perllm::workload::generator::{generate, ArrivalProcess, SloSampling, WorkloadConfig};
use perllm::workload::service::{ServiceClass, ServiceOutcome, ServiceRequest};

/// Pre-PR5 CS-UCB, verbatim: the scalar deadline C1 term, one arm per
/// (class, server), the fused margin/bare scan, the first-max fallback,
/// Eq.-4 reward on completion slack. Kept independent of the production
/// `CsUcb` so a drive-by change there cannot silently rewrite the spec.
/// (`PendingPenalties`' dense-vec storage is replaced by a HashMap — the
/// stored/loaded floats are identical, only the container differs.)
struct ReferenceScalarCsUcb {
    params: CsUcbParams,
    arms: Vec<Vec<(u64, f64)>>, // (pulls, mean_reward)
    t: u64,
    pending: std::collections::HashMap<u64, f64>,
    cum_regret: f64,
    fallback_decisions: u64,
    shed_decisions: u64,
    n_servers: usize,
}

impl ReferenceScalarCsUcb {
    fn new(n_servers: usize) -> Self {
        ReferenceScalarCsUcb {
            params: CsUcbParams::default(),
            arms: vec![vec![(0, 0.0); n_servers]; ServiceClass::ALL.len()],
            t: 0,
            pending: std::collections::HashMap::new(),
            cum_regret: 0.0,
            fallback_decisions: 0,
            shed_decisions: 0,
            n_servers,
        }
    }

    /// The pre-PR5 Eq.-3 formula, literally: scalar deadline slack (no
    /// zero-deadline guard — these workloads draw D∆ in [2, 6]), then the
    /// compute and bandwidth terms, `d.min(c).min(b)`.
    fn scalar_fy(view: &ClusterView, req: &ServiceRequest, j: usize) -> f64 {
        let sv = &view.servers[j];
        let deadline = req.slo.completion.unwrap_or(f64::INFINITY);
        let d = (deadline - sv.predicted_time) / deadline;
        let c = if sv.compute_headroom > 0.0 {
            (sv.compute_headroom - sv.compute_demand) / sv.compute_headroom.max(1e-9)
        } else {
            -1.0
        };
        let b = if sv.bandwidth_headroom > 0.0 {
            (sv.bandwidth_headroom - sv.bandwidth_demand) / sv.bandwidth_headroom.max(1e-9)
        } else {
            -1.0
        };
        d.min(c).min(b)
    }

    fn ucb(&self, class: usize, server: usize) -> f64 {
        let (pulls, mean) = self.arms[class][server];
        if pulls == 0 {
            return f64::INFINITY;
        }
        let t = (self.t.max(2)) as f64;
        mean + self.params.delta * (t.ln() / pulls as f64).sqrt()
    }

    fn best_estimate(&self, class: usize) -> f64 {
        self.arms[class]
            .iter()
            .filter(|(p, _)| *p > 0)
            .map(|(_, m)| *m)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

impl Scheduler for ReferenceScalarCsUcb {
    fn name(&self) -> &'static str {
        "cs-ucb (PerLLM)" // same label: RunReport.scheduler compares equal
    }

    fn decide(&mut self, req: &ServiceRequest, view: &ClusterView) -> Action {
        self.t += 1;
        let class = req.class.index();
        let margin = self.params.slack_margin;
        let mut best_margin: Option<(usize, f64)> = None;
        let mut best_bare: Option<(usize, f64)> = None;
        for j in view.scan() {
            let fy = Self::scalar_fy(view, req, j);
            if fy < 0.0 {
                continue;
            }
            let v = self.ucb(class, j);
            let v = if v.is_infinite() {
                f64::MAX / 2.0
                    - view.energy_cost(j) * 1.0e6
                    - view.servers[j].predicted_time * 1.0e3
                    - view.servers[j].occupancy * 1.0e3
            } else {
                v
            };
            if fy >= margin && best_margin.is_none_or(|(_, bv)| v > bv) {
                best_margin = Some((j, v));
            }
            if best_bare.is_none_or(|(_, bv)| v > bv) {
                best_bare = Some((j, v));
            }
        }
        let (choice, penalty) = match best_margin.or(best_bare) {
            Some((j, _)) => (j, 0.0),
            None => {
                let mut best_fy = f64::NEG_INFINITY;
                let mut least_violating = 0usize;
                for j in 0..view.servers.len() {
                    let fy = Self::scalar_fy(view, req, j);
                    if fy > best_fy {
                        best_fy = fy;
                        least_violating = j;
                    }
                }
                if best_fy < -self.params.shed_threshold {
                    self.shed_decisions += 1;
                    return Action::shed(ShedReason::Infeasible);
                }
                self.fallback_decisions += 1;
                (least_violating, best_fy.min(0.0))
            }
        };
        if penalty < 0.0 {
            self.pending.insert(req.id, penalty);
        }
        Action::assign(choice)
    }

    fn feedback(&mut self, outcome: &ServiceOutcome, _view: &ClusterView) {
        if outcome.was_shed() {
            self.pending.remove(&outcome.id);
            return;
        }
        let class = outcome.class.index();
        let penalty = self.pending.remove(&outcome.id).unwrap_or(0.0);
        // Pre-PR5 Eq. 4: completion slack only.
        let energy_term = outcome.energy_j / 1000.0;
        let deadline = outcome.slo.completion.unwrap_or(f64::INFINITY);
        let fy = ((deadline - outcome.processing_time) / deadline).clamp(-2.0, 1.0);
        let mut r = -energy_term + self.params.lambda * fy;
        if penalty < 0.0 {
            r += self.params.theta * penalty;
        }
        let (pulls, mean) = &mut self.arms[class][outcome.server];
        *pulls += 1;
        *mean += (r - *mean) / *pulls as f64;
        let best = self.best_estimate(class);
        if best.is_finite() {
            let gap = self.params.alpha * self.params.beta * best - r;
            if gap > 0.0 {
                self.cum_regret += gap;
            }
        }
    }

    fn diagnostics(&self) -> Vec<(String, f64)> {
        // Same keys and float pipelines as production CsUcb, so the
        // diagnostics vectors compare equal.
        let explored: u64 = self
            .arms
            .iter()
            .flat_map(|row| row.iter())
            .filter(|(p, _)| *p > 0)
            .count() as u64;
        let m = self.arms.len() as f64;
        let n = self.n_servers as f64;
        let l = (self.t.max(2)) as f64;
        vec![
            ("cum_regret".into(), self.cum_regret),
            ("regret_bound".into(), (2.0 * m * n * l.ln()).sqrt()),
            ("fallback_decisions".into(), self.fallback_decisions as f64),
            ("shed_decisions".into(), self.shed_decisions as f64),
            ("explored_arms".into(), explored as f64),
            ("decisions".into(), self.t as f64),
        ]
    }
}

/// Bit-level equality of two runs over the pinned `RunReport` surface.
fn assert_runs_bit_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: id order");
        assert_eq!(x.server, y.server, "{label}: placement of {}", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens of {}", x.id);
        assert_eq!(
            x.completed_at.to_bits(),
            y.completed_at.to_bits(),
            "{label}: completion instant of {}",
            x.id
        );
        assert_eq!(
            x.processing_time.to_bits(),
            y.processing_time.to_bits(),
            "{label}: processing time of {}",
            x.id
        );
        assert_eq!(
            x.energy_j.to_bits(),
            y.energy_j.to_bits(),
            "{label}: energy of {}",
            x.id
        );
    }
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(a.dropped_by_policy, b.dropped_by_policy, "{label}: policy sheds");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.late, b.late, "{label}: late");
    assert_eq!(
        a.success_rate.to_bits(),
        b.success_rate.to_bits(),
        "{label}: success rate"
    );
    assert_eq!(
        a.energy.total_j().to_bits(),
        b.energy.total_j().to_bits(),
        "{label}: total energy"
    );
    assert_eq!(a.events_processed, b.events_processed, "{label}: events");
    assert_eq!(a.stale_events, b.stale_events, "{label}: stale events");
}

fn completion_only_trace(n: usize, rate: f64, seed: u64) -> Vec<ServiceRequest> {
    generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate })
            .with_deadline_range(2.0, 6.0)
            .with_seed(seed),
    )
}

/// The headline compat pin: on the paper testbed with completion-only
/// contracts, production CS-UCB (completion lens) reproduces the literal
/// pre-PR5 scalar implementation bit for bit — both bandwidth modes,
/// diagnostics included.
#[test]
fn csucb_completion_only_bit_identical_to_scalar_reference() {
    let trace = completion_only_trace(1500, 15.0, 42);
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let cfg = ClusterConfig::paper("llama2-7b", mode);
        let mut current = CsUcb::with_defaults(cfg.n_servers());
        let mut reference = ReferenceScalarCsUcb::new(cfg.n_servers());
        let a = simulate(&cfg, &trace, &mut current);
        let b = simulate(&cfg, &trace, &mut reference);
        assert_runs_bit_identical(&a, &b, &format!("cs-ucb vs scalar ref {mode:?}"));
        assert_eq!(a.diagnostics, b.diagnostics, "{mode:?}: diagnostics");
        assert!(a.success_rate > 0.5, "pinned run does real work");
    }
}

/// Overload pin: the simultaneous-400 collapse regime exercises the
/// fallback scan (first-max tie-break) and the penalty path.
#[test]
fn csucb_scalar_reference_identity_under_overload() {
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(400)
            .with_arrivals(ArrivalProcess::Simultaneous)
            .with_deadline_range(2.0, 6.0)
            .with_seed(3),
    );
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let mut current = CsUcb::with_defaults(cfg.n_servers());
    let mut reference = ReferenceScalarCsUcb::new(cfg.n_servers());
    let a = simulate(&cfg, &trace, &mut current);
    let b = simulate(&cfg, &trace, &mut reference);
    assert_runs_bit_identical(&a, &b, "overload");
    assert_eq!(a.diagnostics, b.diagnostics, "overload diagnostics");
}

/// Vector degeneration: on completion-only contracts `CsUcbSlo` is
/// run-identical to `CsUcb` — the SLO min_slack collapses to the scalar
/// C1 float exactly (pinned across a learning run with feedback).
#[test]
fn csucb_slo_degenerates_to_plain_on_completion_only() {
    let trace = completion_only_trace(1200, 15.0, 9);
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let cfg = ClusterConfig::paper("yi-6b", mode);
        let mut plain = CsUcb::with_defaults(cfg.n_servers());
        let mut slo = CsUcbSlo::with_defaults(cfg.n_servers());
        let a = simulate(&cfg, &trace, &mut plain);
        let b = simulate(&cfg, &trace, &mut slo);
        assert_runs_bit_identical(&a, &b, &format!("slo degeneration {mode:?}"));
    }
}

/// Workload-mode isolation: per-class SLO sampling must not move the
/// physics. With a scheduler that ignores contracts entirely, the two
/// modes produce identical placements and completion instants — only the
/// contract fields, success accounting, and attainment tables differ.
#[test]
fn per_class_sampling_leaves_the_physics_untouched() {
    struct Fixed(usize);
    impl Scheduler for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
            Action::assign(self.0)
        }
    }
    let base = WorkloadConfig::default()
        .with_requests(600)
        .with_arrivals(ArrivalProcess::Poisson { rate: 10.0 })
        .with_seed(21);
    let scalar_trace = generate(&base);
    let vector_trace = generate(&base.clone().with_slo_sampling(SloSampling::PerClass));
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let a = simulate(&cfg, &scalar_trace, &mut Fixed(5));
    let b = simulate(&cfg, &vector_trace, &mut Fixed(5));
    assert_eq!(a.outcomes.len(), b.outcomes.len());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.server, y.server);
        assert_eq!(x.processing_time.to_bits(), y.processing_time.to_bits());
        assert_eq!(x.completed_at.to_bits(), y.completed_at.to_bits());
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        assert_eq!(x.ttft_time.to_bits(), y.ttft_time.to_bits());
    }
    assert_eq!(a.events_processed, b.events_processed);
    // The vector run judges TTFT where the scalar run had nothing to
    // judge: attainment tables populate, success can only tighten.
    let interactive_ttft: usize = [ServiceClass::Chat, ServiceClass::Translate]
        .iter()
        .map(|c| b.ttft_attainment[c.index()].total)
        .sum();
    assert!(interactive_ttft > 0, "per-class mode must add TTFT contracts");
    assert_eq!(
        a.ttft_attainment.iter().map(|t| t.total).sum::<usize>(),
        0,
        "scalar mode has no TTFT contracts"
    );
    assert!(b.success_rate <= a.success_rate + 1e-12);
}

/// The issue's acceptance comparison, pinned conservatively: on the
/// token-batch-edge testbed with per-class contracts, `CsUcbSlo` must
/// not lose to completion-only CS-UCB on interactive-class TTFT
/// attainment, and must hold the total success rate to within a small
/// tolerance. (The strict "beats" demonstration is the
/// `paper_scale_sim --slo per-class` run; a bit-level inequality would
/// be flaky to pin across calibrations.)
#[test]
fn slo_lens_holds_interactive_ttft_attainment_on_token_batch_edge() {
    let wl = WorkloadConfig::default()
        .with_requests(4000)
        .with_arrivals(ArrivalProcess::Poisson { rate: 18.0 })
        .with_seed(42)
        .with_per_class_slos();
    let trace = generate(&wl);
    let cfg = TopologyConfig::paper("llama2-7b", BandwidthMode::Stable)
        .with_service_model_by_name("token-batch-edge")
        .expect("known service model")
        .build();
    let mut plain = CsUcb::with_defaults(cfg.n_servers());
    let mut slo = CsUcbSlo::with_defaults(cfg.n_servers());
    let a = simulate(&cfg, &trace, &mut plain);
    let b = simulate(&cfg, &trace, &mut slo);
    let interactive = |r: &RunReport| {
        let mut met = 0usize;
        let mut total = 0usize;
        for c in [ServiceClass::Chat, ServiceClass::Translate] {
            met += r.ttft_attainment[c.index()].met;
            total += r.ttft_attainment[c.index()].total;
        }
        (met, total)
    };
    let (met_a, total_a) = interactive(&a);
    let (met_b, total_b) = interactive(&b);
    assert_eq!(total_a, total_b, "same contracts judged on both runs");
    assert!(total_a > 0);
    let rate_a = met_a as f64 / total_a as f64;
    let rate_b = met_b as f64 / total_b as f64;
    assert!(
        rate_b + 0.01 >= rate_a,
        "SLO lens lost interactive TTFT attainment: {rate_b:.4} vs {rate_a:.4}"
    );
    assert!(
        b.success_rate + 0.03 >= a.success_rate,
        "SLO lens lost total success: {:.4} vs {:.4}",
        b.success_rate,
        a.success_rate
    );
}
