//! Differential property test: the virtual-work-time `PsQueue` must be
//! observation-equivalent to the seed's naive O(n)-per-advance
//! implementation, which is preserved here verbatim as the shadow
//! reference.
//!
//! Equivalence is checked over randomized push / advance(+energy) / reap /
//! cancel sequences: same completion batches at the same reap instants
//! (hence identical completion timestamps — within a batch the virtual
//! queue orders by (finish work, admission) while the reference's scan
//! order is incidental, so batches compare as id-sets), per-job energy
//! attribution within 1e-9, remaining-work snapshots within 1e-9, and the
//! same backlog and next-completion estimates.

use std::collections::VecDeque;

use perllm::sim::ps::PsQueue;
use perllm::util::proptest::{check, Gen};

/// "Done" threshold, identical to the production constant.
const DONE_EPS_S: f64 = 1e-9;

/// The seed implementation: per-job remaining decremented on every
/// advance, full scans for reap/min/backlog. Kept as the executable
/// specification.
#[derive(Debug, Clone)]
struct NaiveJob {
    id: u64,
    remaining: f64,
    enqueued_at: f64,
    started_at: Option<f64>,
    energy_j: f64,
}

struct NaivePs {
    active: Vec<NaiveJob>,
    waiting: VecDeque<NaiveJob>,
    max_active: usize,
}

impl NaivePs {
    fn new(max_active: usize) -> Self {
        NaivePs {
            active: Vec::new(),
            waiting: VecDeque::new(),
            max_active,
        }
    }

    fn push(&mut self, id: u64, work: f64, now: f64) {
        let mut job = NaiveJob {
            id,
            remaining: work,
            enqueued_at: now,
            started_at: None,
            energy_j: 0.0,
        };
        if self.active.len() < self.max_active {
            job.started_at = Some(now);
            self.active.push(job);
        } else {
            self.waiting.push_back(job);
        }
    }

    fn advance_energy(&mut self, dt: f64, per_job_rate: f64, energy_per_job: f64) {
        if dt == 0.0 {
            return;
        }
        let dec = dt * per_job_rate;
        for j in &mut self.active {
            j.remaining -= dec;
            j.energy_j += energy_per_job;
        }
    }

    fn reap(&mut self, now: f64, per_job_rate: f64) -> Vec<NaiveJob> {
        let eps = (per_job_rate * DONE_EPS_S).max(f64::MIN_POSITIVE);
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].remaining <= eps {
                done.push(self.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        while self.active.len() < self.max_active {
            match self.waiting.pop_front() {
                Some(mut j) => {
                    j.started_at = Some(now);
                    self.active.push(j);
                }
                None => break,
            }
        }
        done
    }

    fn next_completion_in(&self, per_job_rate: f64) -> Option<f64> {
        if per_job_rate <= 0.0 {
            return None;
        }
        self.active
            .iter()
            .map(|j| (j.remaining.max(0.0)) / per_job_rate)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    fn cancel(&mut self, id: u64, now: f64) -> Option<NaiveJob> {
        if let Some(i) = self.active.iter().position(|j| j.id == id) {
            let job = self.active.swap_remove(i);
            if let Some(mut w) = self.waiting.pop_front() {
                w.started_at = Some(now);
                self.active.push(w);
            }
            return Some(job);
        }
        if let Some(i) = self.waiting.iter().position(|j| j.id == id) {
            return self.waiting.remove(i);
        }
        None
    }

    fn backlog(&self) -> f64 {
        self.active.iter().map(|j| j.remaining).sum::<f64>()
            + self.waiting.iter().map(|j| j.remaining).sum::<f64>()
    }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
}

/// Compare the queues' externally-observable state.
fn assert_state_equiv(v: &PsQueue, n: &NaivePs, ctx: &str) {
    assert_eq!(v.n_active(), n.active.len(), "{ctx}: n_active");
    assert_eq!(v.n_waiting(), n.waiting.len(), "{ctx}: n_waiting");
    assert!(
        close(v.backlog(), n.backlog().max(0.0)) || close(v.backlog(), n.backlog()),
        "{ctx}: backlog {} vs {}",
        v.backlog(),
        n.backlog()
    );
    // Every reference job is visible in the virtual queue with the same
    // remaining work, energy, and service timestamps.
    for j in n.active.iter().chain(n.waiting.iter()) {
        let vj = v
            .job(j.id)
            .unwrap_or_else(|| panic!("{ctx}: job {} missing", j.id));
        assert!(
            close(vj.remaining, j.remaining),
            "{ctx}: job {} remaining {} vs {}",
            j.id,
            vj.remaining,
            j.remaining
        );
        assert!(
            close(vj.energy_j, j.energy_j),
            "{ctx}: job {} energy {} vs {}",
            j.id,
            vj.energy_j,
            j.energy_j
        );
        assert_eq!(vj.started_at, j.started_at, "{ctx}: job {} started_at", j.id);
        assert_eq!(vj.enqueued_at, j.enqueued_at, "{ctx}: job {} enqueued_at", j.id);
    }
}

#[test]
fn virtual_time_queue_matches_naive_reference() {
    check("ps virtual-time equivalence", 200, |g: &mut Gen| {
        let max_active = g.usize(1, 6);
        let mut v = PsQueue::new(max_active);
        let mut n = NaivePs::new(max_active);
        let mut now = 0.0f64;
        let mut next_id = 0u64;
        let ops = g.usize(1, 80);
        for op in 0..ops {
            match g.usize(0, 9) {
                0..=3 => {
                    let work = g.f64(0.1, 5.0);
                    v.push(next_id, work, now);
                    n.push(next_id, work, now);
                    next_id += 1;
                }
                4..=6 => {
                    // Random-interval advance with energy, then reap.
                    let rate = if g.chance(0.15) { 0.0 } else { g.f64(0.1, 3.0) };
                    let dt = g.f64(0.0, 2.0);
                    let e = g.f64(0.0, 2.0);
                    v.advance_energy(dt, rate, e);
                    n.advance_energy(dt, rate, e);
                    now += dt;
                    compare_reap(&mut v, &mut n, now, rate, op);
                }
                7 => {
                    // Advance exactly to the next completion boundary (the
                    // engine's own stepping pattern).
                    let rate = g.f64(0.1, 3.0);
                    if let Some(eta) = n.next_completion_in(rate) {
                        let v_eta = v
                            .next_completion_in(rate)
                            .expect("virtual queue must also have a completion");
                        assert!(
                            close(eta, v_eta),
                            "op {op}: eta {eta} vs {v_eta}"
                        );
                        let e = g.f64(0.0, 2.0);
                        v.advance_energy(eta, rate, e);
                        n.advance_energy(eta, rate, e);
                        now += eta;
                        let done = compare_reap(&mut v, &mut n, now, rate, op);
                        assert!(done > 0, "op {op}: boundary advance must complete a job");
                    }
                }
                8 => {
                    if next_id > 0 {
                        let target = g.u64(0, next_id - 1);
                        let cv = v.cancel(target, now);
                        let cn = n.cancel(target, now);
                        assert_eq!(cv.is_some(), cn.is_some(), "op {op}: cancel {target}");
                        if let (Some(a), Some(b)) = (cv, cn) {
                            assert_eq!(a.id, b.id);
                            assert!(close(a.remaining, b.remaining));
                            assert!(close(a.energy_j, b.energy_j));
                            assert_eq!(a.started_at, b.started_at);
                        }
                    }
                }
                _ => {
                    let rate = g.f64(0.1, 3.0);
                    match (v.next_completion_in(rate), n.next_completion_in(rate)) {
                        (None, None) => {}
                        (Some(a), Some(b)) => {
                            assert!(close(a, b), "op {op}: next completion {a} vs {b}")
                        }
                        (a, b) => panic!("op {op}: next completion {a:?} vs {b:?}"),
                    }
                }
            }
            assert_state_equiv(&v, &n, &format!("op {op}"));
        }
    });
}

/// Reap both queues at the same instant and require identical completion
/// batches: same ids (order within a batch is compared as a set — the
/// completion *timestamps* are equal by construction since the batch
/// boundary is shared), same energy, both within the done-threshold.
fn compare_reap(v: &mut PsQueue, n: &mut NaivePs, now: f64, rate: f64, op: usize) -> usize {
    let mut dv = v.reap(now, rate);
    let mut dn = n.reap(now, rate);
    dv.sort_by_key(|j| j.id);
    dn.sort_by_key(|j| j.id);
    assert_eq!(
        dv.iter().map(|j| j.id).collect::<Vec<_>>(),
        dn.iter().map(|j| j.id).collect::<Vec<_>>(),
        "op {op}: completion batch mismatch"
    );
    for (a, b) in dv.iter().zip(&dn) {
        assert!(
            close(a.energy_j, b.energy_j),
            "op {op}: job {} completion energy {} vs {}",
            a.id,
            a.energy_j,
            b.energy_j
        );
        assert_eq!(a.started_at, b.started_at, "op {op}: job {} started_at", a.id);
        assert_eq!(a.enqueued_at, b.enqueued_at, "op {op}: job {} enqueued_at", a.id);
    }
    dv.len()
}

/// The virtual queue's intra-batch order is deterministic and principled:
/// earliest finish work first, admission order on ties. (The naive
/// reference's batch order is a swap_remove artifact, which is why batches
/// compare as sets above.)
#[test]
fn intra_batch_order_is_finish_then_fifo() {
    let mut q = PsQueue::new(8);
    q.push(10, 2.0, 0.0); // finishes at work 2
    q.push(11, 1.0, 0.0); // finishes at work 1
    q.push(12, 2.0, 0.0); // ties with 10, admitted later
    q.advance(2.0, 1.0);
    let done = q.reap(2.0, 1.0);
    assert_eq!(
        done.iter().map(|j| j.id).collect::<Vec<_>>(),
        vec![11, 10, 12]
    );
}
