//! Run-identity pin for the `ServiceModel` refactor: the PS default must
//! be **bit-identical** to the pre-trait server layer.
//!
//! `ReferencePsModel` below is the pre-PR-4 `ServerSim` service logic,
//! copied formula for formula onto the trait — an executable
//! specification in the spirit of `ps_equivalence.rs` (which keeps the
//! seed's naive PS queue) and PR 3's topology-lowering pin. Each test
//! builds the engine twice over `ClusterConfig::paper` + a seeded
//! workload — once with the production `PsServiceModel`, once with every
//! server swapped to the reference — and requires the two `RunReport`s to
//! agree outcome for outcome, to the bit: success counts, energy,
//! completion instants, event counts, per-scheduler diagnostics.
//!
//! If a future change to `PsServiceModel` (or the engine's model-agnostic
//! reschedule path) moves any float by one ulp, this fails — exactly the
//! alarm the refactor promised.

use perllm::scheduler::csucb::CsUcb;
use perllm::scheduler::{
    agod::Agod, fineinfer::FineInfer, rewardless::RewardlessGuidance, Scheduler,
};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::{simulate, Engine, RunReport};
use perllm::sim::ps::{batch_efficiency, PsJob, PsQueue};
use perllm::sim::server::ServerSpec;
use perllm::sim::service_model::{ServiceModel, ServicePrediction};
use perllm::sim::time::SimTime;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};
use perllm::workload::service::ServiceRequest;
use perllm::workload::TraceSource;

/// The pre-trait `ServerSim` service internals, verbatim: a `PsQueue`
/// over solo-work seconds, rate `rate_mult * eff(n) / n` per job, the
/// historical predictor. Kept independent of `PsServiceModel` so a
/// drive-by "simplification" there cannot silently rewrite the spec.
#[derive(Debug)]
struct ReferencePsModel {
    spec: ServerSpec,
    queue: PsQueue,
}

impl ReferencePsModel {
    fn new(spec: ServerSpec) -> Self {
        let slots = spec.slots;
        ReferencePsModel {
            spec,
            queue: PsQueue::new(slots),
        }
    }

    /// Pre-trait `ServerSim::per_job_rate`.
    fn per_job_rate(&self, rate_mult: f64) -> f64 {
        let n = self.queue.n_active();
        if n == 0 {
            return 0.0;
        }
        rate_mult * batch_efficiency(n, self.spec.batch_alpha) / n as f64
    }
}

impl ServiceModel for ReferencePsModel {
    fn admit(&mut self, id: u64, req: &ServiceRequest, now: SimTime) {
        // Pre-trait engine: `srv.queue.push(id, spec.solo_work(req), now)`.
        self.queue.push(id, self.spec.solo_work(req), now);
    }

    fn would_drop(&self) -> bool {
        // Pre-trait `ServerSim::would_drop`.
        self.queue.n_active() >= self.queue.max_active()
            && self.queue.n_waiting() >= self.spec.queue_limit
    }

    fn advance(&mut self, dt: SimTime, rate_mult: f64, energy_per_job: f64) {
        // Pre-trait `ServerSim::advance_to` body (rate fixed over dt).
        let rate = self.per_job_rate(rate_mult);
        self.queue.advance_energy(dt, rate, energy_per_job);
    }

    fn next_completion_in(&self, rate_mult: f64) -> Option<SimTime> {
        self.queue.next_completion_in(self.per_job_rate(rate_mult))
    }

    fn completion_key(&self, rate_mult: f64) -> Option<(f64, f64)> {
        // Pre-trait `Engine::reschedule_server` guard inputs:
        // (heap-top finish work, per-job rate), present iff rate > 0.
        let rate = self.per_job_rate(rate_mult);
        if rate > 0.0 {
            self.queue.peek_finish_work().map(|fw| (fw, rate))
        } else {
            None
        }
    }

    fn reap_into(&mut self, now: SimTime, rate_mult: f64, out: &mut Vec<PsJob>) {
        let rate = self.per_job_rate(rate_mult);
        self.queue.reap_into(now, rate, out);
    }

    fn predict(
        &self,
        req: &ServiceRequest,
        extra_n: usize,
        extra_work_s: f64,
        rate_mult: f64,
    ) -> ServicePrediction {
        // Pre-trait `ServerSim::predict_service_time_with`, verbatim.
        let work = self.spec.solo_work(req);
        let occupied = self.queue.n_active() + extra_n;
        let n_after = (occupied + 1).min(self.queue.max_active());
        let eff = batch_efficiency(n_after, self.spec.batch_alpha).max(1e-9);
        let stretch = n_after as f64 / eff;
        let mult = if rate_mult > 0.0 { rate_mult } else { 1e-9 };
        let wait = if occupied >= self.queue.max_active() {
            (self.queue.backlog() + extra_work_s) / (eff * mult)
        } else {
            0.0
        };
        let prefill_s = req.prompt_tokens as f64 / self.spec.prefill_rate;
        ServicePrediction {
            ttft_s: wait + prefill_s * stretch / mult,
            total_s: wait + work * stretch / mult,
        }
    }

    fn n_active(&self) -> usize {
        self.queue.n_active()
    }

    fn n_waiting(&self) -> usize {
        self.queue.n_waiting()
    }

    fn slot_capacity(&self) -> usize {
        self.queue.max_active()
    }

    fn queue_capacity(&self) -> usize {
        self.spec.queue_limit
    }

    fn backlog_s(&self) -> f64 {
        self.queue.backlog()
    }
}

/// Run `trace` through the engine with every server forced onto the
/// reference model.
fn simulate_reference(
    cfg: &ClusterConfig,
    trace: &[ServiceRequest],
    scheduler: &mut dyn Scheduler,
) -> RunReport {
    let mut source = TraceSource::new(trace);
    let mut engine = Engine::new(cfg, &mut source, scheduler);
    for srv in &mut engine.cluster_mut().servers {
        srv.model = Box::new(ReferencePsModel::new(srv.spec.clone()));
    }
    engine.run()
}

/// Bit-level equality of two runs: the pinned `RunReport` surface
/// (success counts, energy, per-outcome instants, event accounting,
/// diagnostics).
fn assert_runs_bit_identical(a: &RunReport, b: &RunReport, label: &str) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome count");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: id order");
        assert_eq!(x.server, y.server, "{label}: placement of {}", x.id);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens of {}", x.id);
        assert_eq!(
            x.completed_at.to_bits(),
            y.completed_at.to_bits(),
            "{label}: completion instant of {}",
            x.id
        );
        assert_eq!(
            x.processing_time.to_bits(),
            y.processing_time.to_bits(),
            "{label}: processing time of {}",
            x.id
        );
        assert_eq!(
            x.energy_j.to_bits(),
            y.energy_j.to_bits(),
            "{label}: energy of {}",
            x.id
        );
    }
    assert_eq!(a.dropped, b.dropped, "{label}: dropped");
    assert_eq!(a.dropped_by_policy, b.dropped_by_policy, "{label}: policy sheds");
    assert_eq!(a.unfinished, b.unfinished, "{label}: unfinished");
    assert_eq!(a.late, b.late, "{label}: late");
    assert_eq!(
        a.success_rate.to_bits(),
        b.success_rate.to_bits(),
        "{label}: success rate"
    );
    assert_eq!(
        a.energy.total_j().to_bits(),
        b.energy.total_j().to_bits(),
        "{label}: total energy"
    );
    assert_eq!(a.events_processed, b.events_processed, "{label}: events");
    assert_eq!(a.stale_events, b.stale_events, "{label}: stale events");
    assert_eq!(
        a.peak_event_queue_len, b.peak_event_queue_len,
        "{label}: peak event heap"
    );
    assert_eq!(a.diagnostics, b.diagnostics, "{label}: diagnostics");
}

fn paper_trace(n: usize, rate: f64, seed: u64) -> Vec<ServiceRequest> {
    generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_arrivals(ArrivalProcess::Poisson { rate })
            .with_deadline_range(2.0, 6.0)
            .with_seed(seed),
    )
}

/// The headline pin: `ClusterConfig::paper` + seeded workload + CS-UCB,
/// both bandwidth modes, trait-based PS vs the pre-trait reference.
#[test]
fn csucb_paper_runs_bit_identical_to_pre_trait_reference() {
    let trace = paper_trace(1500, 15.0, 42);
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let cfg = ClusterConfig::paper("llama2-7b", mode);
        let mut s1 = CsUcb::with_defaults(cfg.n_servers());
        let mut s2 = CsUcb::with_defaults(cfg.n_servers());
        let current = simulate(&cfg, &trace, &mut s1);
        let reference = simulate_reference(&cfg, &trace, &mut s2);
        assert_runs_bit_identical(&current, &reference, &format!("cs-ucb {mode:?}"));
        // Sanity: the pinned run does real work.
        assert!(current.success_rate > 0.5);
        assert!(current.energy.total_j() > 0.0);
    }
}

/// Every baseline scheduler sees the same identity (placement feedback
/// loops differ per policy, so each exercises different view/feedback
/// paths through the trait).
#[test]
fn baselines_paper_runs_bit_identical_to_pre_trait_reference() {
    let trace = paper_trace(1000, 15.0, 7);
    let cfg = ClusterConfig::paper("yi-6b", BandwidthMode::Stable);
    let mk: Vec<(&str, Box<dyn Fn() -> Box<dyn Scheduler>>)> = vec![
        (
            "fineinfer",
            Box::new(|| Box::new(FineInfer::new(5)) as Box<dyn Scheduler>),
        ),
        (
            "agod",
            Box::new(|| Box::new(Agod::new(6, 7)) as Box<dyn Scheduler>),
        ),
        (
            "rewardless",
            Box::new(|| Box::new(RewardlessGuidance::new(6)) as Box<dyn Scheduler>),
        ),
    ];
    for (name, make) in mk {
        let mut s1 = make();
        let mut s2 = make();
        let current = simulate(&cfg, &trace, s1.as_mut());
        let reference = simulate_reference(&cfg, &trace, s2.as_mut());
        assert_runs_bit_identical(&current, &reference, name);
    }
}

/// The overload/outage paths (admission drops, zero-rate servers,
/// horizon-unfinished work) also run bit-identical through the trait.
#[test]
fn stress_paths_bit_identical_to_pre_trait_reference() {
    use perllm::scheduler::{Action, ClusterView};

    struct Fixed(usize);
    impl Scheduler for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
            Action::assign(self.0)
        }
    }

    // Simultaneous burst onto the cloud: congestion collapse, queue
    // drops, heavy reschedule churn — the guard's hottest path.
    let burst = generate(
        &WorkloadConfig::default()
            .with_requests(400)
            .with_arrivals(ArrivalProcess::Simultaneous)
            .with_seed(3),
    );
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
    let current = simulate(&cfg, &burst, &mut Fixed(5));
    let reference = simulate_reference(&cfg, &burst, &mut Fixed(5));
    assert_runs_bit_identical(&current, &reference, "simultaneous-400");
    assert!(current.dropped > 0, "stress run must actually shed");

    // Outage window on the target server: zero-rate completion keys.
    let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable).with_outages(vec![
        perllm::sim::cluster::Outage {
            server: 0,
            start: 0.5,
            end: 3.0,
        },
    ]);
    let trace = paper_trace(120, 10.0, 13);
    let current = simulate(&cfg, &trace, &mut Fixed(0));
    let reference = simulate_reference(&cfg, &trace, &mut Fixed(0));
    assert_runs_bit_identical(&current, &reference, "outage");
}
