//! Tier-1 harness for pallas-lint (src/analysis/): the whole `src/**`
//! tree must be clean under every rule, and every rule must actually
//! fire on its known-bad fixture and stay quiet on the annotated
//! known-good twin.
//!
//! Fixtures live in `tests/lint_fixtures/` — a subdirectory, so cargo
//! never compiles them — and are linted under a virtual `sim/` path to
//! land inside the strictest rule scope.

use perllm::analysis::lint_tree;
use perllm::analysis::rules::lint_source;
use std::path::Path;

/// The self-clean gate: zero unsuppressed violations across the crate.
/// This is the same check CI runs via `cargo run --bin pallas-lint`.
#[test]
fn src_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("walk src tree");
    // Guard against the walker silently linting nothing (wrong root,
    // broken recursion): the crate has ~47 source files today.
    assert!(
        report.files >= 40,
        "suspiciously few files linted: {}",
        report.files
    );
    let mut msg = String::new();
    for d in &report.diagnostics {
        msg.push_str(&format!("\n  {d}"));
    }
    assert!(
        report.diagnostics.is_empty(),
        "pallas-lint violations in src/**:{msg}"
    );
}

struct Case {
    name: &'static str,
    src: &'static str,
    /// Expected (line, rule) pairs, in diagnostic order (line, then rule).
    expect: &'static [(u32, &'static str)],
}

/// Every rule fires on its known-bad fixture at the expected lines, and
/// the annotated known-good twin is silent — both linted under a
/// virtual `sim/` path (the strictest scope).
#[test]
fn fixtures_fire_and_suppress_as_documented() {
    const CASES: &[Case] = &[
        Case {
            name: "d1_bad",
            src: include_str!("lint_fixtures/d1_bad.rs"),
            expect: &[(7, "D1"), (12, "D1")],
        },
        Case {
            name: "d1_good",
            src: include_str!("lint_fixtures/d1_good.rs"),
            expect: &[],
        },
        Case {
            name: "d2_bad",
            src: include_str!("lint_fixtures/d2_bad.rs"),
            expect: &[(7, "D2")],
        },
        Case {
            name: "d2_good",
            src: include_str!("lint_fixtures/d2_good.rs"),
            expect: &[],
        },
        Case {
            name: "d3_bad",
            src: include_str!("lint_fixtures/d3_bad.rs"),
            expect: &[(6, "D3")],
        },
        Case {
            name: "d3_good",
            src: include_str!("lint_fixtures/d3_good.rs"),
            expect: &[],
        },
        // The session side-stream pair (PR 10): seeding a conversation
        // generator from the workload seed directly fires D3 (that is
        // precisely how sessions could perturb the base stream); the
        // `seed ^ SESSION_STREAM_SALT` idiom `workload::sessions` uses
        // is silent.
        Case {
            name: "d3_session_bad",
            src: include_str!("lint_fixtures/d3_session_bad.rs"),
            expect: &[(8, "D3")],
        },
        Case {
            name: "d3_session_good",
            src: include_str!("lint_fixtures/d3_session_good.rs"),
            expect: &[],
        },
        Case {
            name: "a1_bad",
            src: include_str!("lint_fixtures/a1_bad.rs"),
            expect: &[(5, "A1"), (9, "A1")],
        },
        Case {
            name: "a1_good",
            src: include_str!("lint_fixtures/a1_good.rs"),
            expect: &[],
        },
        // The shard grant-window pair (PR 8): the per-shard hot loop in
        // sim/shard.rs declares a no-alloc region over grant execution;
        // these fixtures pin that an allocating drain (fresh Vec + a
        // collect) fires A1, and the recycled-buffer rewrite — the real
        // Cmd/Reply buffer round-trip contract — is silent.
        Case {
            name: "a1_shard_bad",
            src: include_str!("lint_fixtures/a1_shard_bad.rs"),
            expect: &[(6, "A1"), (12, "A1")],
        },
        Case {
            name: "a1_shard_good",
            src: include_str!("lint_fixtures/a1_shard_good.rs"),
            expect: &[],
        },
        Case {
            name: "p1_bad",
            src: include_str!("lint_fixtures/p1_bad.rs"),
            expect: &[(4, "P1"), (6, "P1")],
        },
        Case {
            name: "p1_good",
            src: include_str!("lint_fixtures/p1_good.rs"),
            expect: &[],
        },
        Case {
            name: "n1_bad",
            src: include_str!("lint_fixtures/n1_bad.rs"),
            expect: &[(6, "N1"), (12, "N1"), (12, "P1")],
        },
        Case {
            name: "n1_good",
            src: include_str!("lint_fixtures/n1_good.rs"),
            expect: &[],
        },
        Case {
            name: "syntax_bad",
            src: include_str!("lint_fixtures/syntax_bad.rs"),
            // Malformed directives are diagnostics themselves AND fail
            // to suppress, so the unwraps still fire.
            expect: &[(4, "lint-syntax"), (5, "P1"), (6, "lint-syntax"), (7, "P1")],
        },
    ];
    for case in CASES {
        let got: Vec<(u32, &str)> = lint_source("sim/fixture.rs", case.src)
            .into_iter()
            .map(|d| (d.line, d.rule))
            .collect();
        assert_eq!(
            got, case.expect,
            "fixture {} fired unexpectedly (got left, expected right)",
            case.name
        );
    }
}

/// Scope end-to-end: the same wall-clock fixture that fires under a
/// `sim/` path is legal in `coordinator/` (where real time is the
/// point) — and the hash-iteration fixture is legal outside the
/// deterministic modules.
#[test]
fn scoping_exempts_the_right_modules() {
    let d1 = include_str!("lint_fixtures/d1_bad.rs");
    assert!(
        lint_source("coordinator/fixture.rs", d1).is_empty(),
        "coordinator/ may read wall clocks"
    );
    assert_eq!(lint_source("sim/fixture.rs", d1).len(), 2);

    let d2 = include_str!("lint_fixtures/d2_bad.rs");
    assert!(
        lint_source("bench/fixture.rs", d2).is_empty(),
        "bench/ is outside the D2 determinism scope"
    );
}
