//! §Perf micro-benchmarks — the L3 hot paths (DESIGN.md §8):
//!
//! * CS-UCB decision latency (must be negligible vs service times)
//! * DES event throughput (events/s — drives experiment wall time)
//! * PS-queue operations
//! * end-to-end simulation wall time per 1 000 requests
//!
//! Run: cargo bench --bench micro_hotpath

mod common;

use perllm::bench::{bench_fn, Table};
use perllm::scheduler::csucb::CsUcb;
use perllm::scheduler::Scheduler;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig, ClusterSim};
use perllm::sim::engine::simulate;
use perllm::sim::ps::PsQueue;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    let mut rows = Vec::new();

    // 1. Scheduler decision latency on a live-ish view.
    {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let sim = ClusterSim::new(&cfg);
        let trace = generate(&WorkloadConfig::default().with_requests(64).with_seed(5));
        let view = sim.view(&trace[0], 0.0);
        let mut sched = CsUcb::with_defaults(cfg.n_servers());
        let mut i = 0usize;
        rows.push(bench_fn("cs-ucb decide()", 1_000, 100_000, || {
            let req = &trace[i % trace.len()];
            std::hint::black_box(sched.decide(req, &view));
            i += 1;
        }));
    }

    // 2. PS queue push/advance/reap cycle.
    {
        let mut q = PsQueue::new(16);
        let mut id = 0u64;
        rows.push(bench_fn("ps push+advance+reap", 1_000, 100_000, || {
            q.push(id, 1.0, 0.0);
            q.advance(0.5, 2.0);
            std::hint::black_box(q.reap(0.5, 2.0));
            id += 1;
        }));
    }

    // 3. Full DES runs (events/s reported separately).
    for &n in &[1_000usize, 4_000] {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_deadline_range(2.0, 6.0)
                .with_seed(42),
        );
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let mut events_per_sec = 0.0;
        let name = format!("simulate cs-ucb {n} reqs");
        rows.push(bench_fn(&name, 1, 5, || {
            let mut s = CsUcb::with_defaults(cfg.n_servers());
            let rep = simulate(&cfg, &trace, &mut s);
            events_per_sec = rep.events_per_sec;
            std::hint::black_box(rep.success_rate);
        }));
        println!("  {n} reqs: DES {events_per_sec:.0} events/s");
    }

    let mut t = Table::new("L3 hot-path micro benches", &["bench"]);
    let _ = &mut t;
    println!();
    for r in &rows {
        println!("{}", r.row());
    }
}
