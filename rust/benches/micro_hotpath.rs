//! §Perf micro-benchmarks — the L3 hot paths (DESIGN.md §8):
//!
//! * CS-UCB decision latency (must be negligible vs service times)
//! * DES event throughput (events/s — drives experiment wall time)
//! * PS-queue operations
//! * the congested-cloud stress case: 400 simultaneous arrivals on one
//!   server, the regime where the seed's O(active-jobs)-per-event queue
//!   went quadratic-ish (the virtual-time core's headline win)
//! * end-to-end simulation wall time per 1 000 / 4 000 requests
//! * a 10x EdgeShard-style topology (60 servers) streaming run — the
//!   calendar-queue + candidate-pruning scale scenario
//! * a sessioned 100x run (multi-turn chains + per-server prefix caches
//!   under the cache-affinity scheduler) — what the session machinery
//!   costs on the hot path, and the hit rate it converts
//!
//! Run: cargo bench --bench micro_hotpath
//!
//! Emits the measured numbers to BENCH_perllm.current.json at the repo
//! root (override with PERLLM_BENCH_JSON=path, disable with =skip);
//! merge them into the committed BENCH_perllm.json when they move.

use perllm::bench::{bench_fn, render_json, JsonValue};
use perllm::scheduler::csucb::{CsUcb, CsUcbAffinity};
use perllm::scheduler::{Action, ClusterView, Scheduler};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig, ClusterSim};
use perllm::sim::engine::{simulate, simulate_stream, simulate_stream_sharded};
use perllm::sim::ps::PsQueue;
use perllm::sim::topology::{ShardCount, TopologyConfig};
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig, WorkloadGen};
use perllm::workload::service::ServiceRequest;
use perllm::workload::sessions::{SessionConfig, SessionSource};

/// Fixed-target scheduler: isolates DES throughput from decision logic.
struct Fixed(usize);
impl Scheduler for Fixed {
    fn name(&self) -> &'static str {
        "fixed"
    }
    fn decide(&mut self, _r: &ServiceRequest, _v: &ClusterView) -> Action {
        Action::assign(self.0)
    }
}

fn main() {
    let mut rows = Vec::new();
    let mut json: Vec<(&str, JsonValue)> = Vec::new();

    // 1. Scheduler decision latency on a live-ish view.
    {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let sim = ClusterSim::new(&cfg);
        let trace = generate(&WorkloadConfig::default().with_requests(64).with_seed(5));
        let view = sim.view(&trace[0], 0.0);
        let mut sched = CsUcb::with_defaults(cfg.n_servers());
        let mut i = 0usize;
        let r = bench_fn("cs-ucb decide()", 1_000, 100_000, || {
            let req = &trace[i % trace.len()];
            std::hint::black_box(sched.decide(req, &view));
            i += 1;
        });
        json.push(("csucb_decide_mean_ns", JsonValue::Num(r.mean_ns)));
        rows.push(r);
    }

    // 2. PS queue push/advance/reap cycle.
    {
        let mut q = PsQueue::new(16);
        let mut id = 0u64;
        let r = bench_fn("ps push+advance+reap", 1_000, 100_000, || {
            q.push(id, 1.0, 0.0);
            q.advance(0.5, 2.0);
            std::hint::black_box(q.reap(0.5, 2.0));
            id += 1;
        });
        json.push(("ps_cycle_mean_ns", JsonValue::Num(r.mean_ns)));
        rows.push(r);
    }

    // 3. Congested cloud: 400 simultaneous arrivals forced onto the cloud
    //    server behind the shared uplink. Every event used to touch all
    //    ~400 concurrent uploads; with the virtual-time core each event is
    //    O(log n). This is the acceptance scenario for the ≥3x events/s
    //    win.
    {
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(400)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_seed(3),
        );
        let cloud = cfg.cloud_index();
        // All JSON metrics use last-iteration semantics (consistent with
        // the csucb rows below) so cross-PR ratios compare like with like.
        let mut events_per_sec = 0.0f64;
        let mut stale_ratio = 0.0f64;
        let mut events: u64 = 0;
        let r = bench_fn("congested cloud 400 simultaneous", 1, 10, || {
            let mut s = Fixed(cloud);
            let rep = simulate(&cfg, &trace, &mut s);
            events_per_sec = rep.events_per_sec;
            stale_ratio = rep.stale_ratio;
            events = rep.events_processed;
            std::hint::black_box(rep.success_rate);
        });
        println!(
            "  congested cloud: {events} events, {events_per_sec:.0} events/s, \
             stale ratio {stale_ratio:.3}"
        );
        json.push(("congested_cloud_400_events_per_sec", JsonValue::Num(events_per_sec)));
        json.push(("congested_cloud_400_stale_ratio", JsonValue::Num(stale_ratio)));
        json.push(("congested_cloud_400_events", JsonValue::Num(events as f64)));
        rows.push(r);
    }

    // 4. Full DES runs (events/s reported separately).
    for &n in &[1_000usize, 4_000] {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_deadline_range(2.0, 6.0)
                .with_seed(42),
        );
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let mut events_per_sec = 0.0;
        let mut stale_ratio = 0.0;
        let name = format!("simulate cs-ucb {n} reqs");
        rows.push(bench_fn(&name, 1, 5, || {
            let mut s = CsUcb::with_defaults(cfg.n_servers());
            let rep = simulate(&cfg, &trace, &mut s);
            events_per_sec = rep.events_per_sec;
            stale_ratio = rep.stale_ratio;
            std::hint::black_box(rep.success_rate);
        }));
        println!("  {n} reqs: DES {events_per_sec:.0} events/s, stale ratio {stale_ratio:.3}");
        if n == 4_000 {
            json.push(("csucb_4000_events_per_sec", JsonValue::Num(events_per_sec)));
            json.push(("csucb_4000_stale_ratio", JsonValue::Num(stale_ratio)));
        }
    }

    // 5. Streaming arrivals: same 4000-request cs-ucb run fed through a
    //    WorkloadGen cursor instead of a materialized trace. Wall time must
    //    match the trace path (identical event sequence) while the event
    //    heap stays bounded by in-flight concurrency.
    {
        let workload = WorkloadConfig::default()
            .with_requests(4_000)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42);
        let cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Fluctuating);
        let mut peak_heap = 0usize;
        rows.push(bench_fn("simulate cs-ucb 4000 reqs (stream)", 1, 5, || {
            let mut s = CsUcb::with_defaults(cfg.n_servers());
            let mut source = WorkloadGen::new(&workload);
            let rep = simulate_stream(&cfg, &mut source, &mut s);
            peak_heap = rep.peak_event_queue_len;
            std::hint::black_box(rep.success_rate);
        }));
        println!("  streaming 4000 reqs: peak event heap {peak_heap}");
        json.push(("streaming_4000_peak_event_heap", JsonValue::Num(peak_heap as f64)));
    }

    // 6. 10x multi-tier topology: 20k requests streamed through the
    //    60-server EdgeShard-style preset at capacity-scaled load. This is
    //    the scenario the calendar event queue and the candidate-pruned
    //    decision path exist for: events/s here tracks how the engine
    //    scales with cluster size, and the peak heap must stay bounded by
    //    in-flight concurrency at ~10x the paper's arrival rate.
    {
        let topo = TopologyConfig::edgeshard_10x("llama2-7b", BandwidthMode::Stable);
        let cfg = topo.build();
        let workload = WorkloadConfig::default()
            .with_requests(20_000)
            .with_arrivals(ArrivalProcess::Poisson {
                rate: topo.scaled_rate(15.0),
            })
            .with_deadline_range(2.0, 6.0)
            .with_seed(42);
        let mut events_per_sec = 0.0;
        let mut stale_ratio = 0.0;
        let mut peak_heap = 0usize;
        rows.push(bench_fn("simulate cs-ucb 20k reqs (10x topology)", 1, 3, || {
            let mut s = CsUcb::with_defaults(cfg.n_servers());
            let mut source = WorkloadGen::new(&workload);
            let rep = simulate_stream(&cfg, &mut source, &mut s);
            events_per_sec = rep.events_per_sec;
            stale_ratio = rep.stale_ratio;
            peak_heap = rep.peak_event_queue_len;
            std::hint::black_box(rep.success_rate);
        }));
        println!(
            "  10x topology 20k reqs: DES {events_per_sec:.0} events/s, \
             stale ratio {stale_ratio:.3}, peak event heap {peak_heap}"
        );
        json.push(("topo10x_20k_events_per_sec", JsonValue::Num(events_per_sec)));
        json.push(("topo10x_20k_stale_ratio", JsonValue::Num(stale_ratio)));
        json.push(("topo10x_20k_peak_event_heap", JsonValue::Num(peak_heap as f64)));
    }

    // 7. Token-batch service model: the same 4000-request cs-ucb run with
    //    every server on the discrete-iteration continuous-batching model
    //    (`--service-model token-batch`). Tracks what the iteration-
    //    granular completion schedule costs relative to the PS fluid's
    //    O(1) virtual-time bumps (row 4) — the price of batching-accurate
    //    physics on the event hot path.
    {
        let topo = TopologyConfig::paper("llama2-7b", BandwidthMode::Fluctuating)
            .with_service_model_by_name("token-batch")
            .expect("known service model");
        let cfg = topo.build();
        let workload = WorkloadConfig::default()
            .with_requests(4_000)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42);
        let mut events_per_sec = 0.0;
        let mut stale_ratio = 0.0;
        let mut success = 0.0;
        rows.push(bench_fn("simulate cs-ucb 4000 reqs (token-batch)", 1, 5, || {
            let mut s = CsUcb::with_defaults(cfg.n_servers());
            let mut source = WorkloadGen::new(&workload);
            let rep = simulate_stream(&cfg, &mut source, &mut s);
            events_per_sec = rep.events_per_sec;
            stale_ratio = rep.stale_ratio;
            success = rep.success_rate;
            std::hint::black_box(rep.success_rate);
        }));
        println!(
            "  token-batch 4000 reqs: DES {events_per_sec:.0} events/s, \
             stale ratio {stale_ratio:.3}, success {success:.3}"
        );
        json.push(("tokenbatch_4000_events_per_sec", JsonValue::Num(events_per_sec)));
        json.push(("tokenbatch_4000_stale_ratio", JsonValue::Num(stale_ratio)));
        json.push(("tokenbatch_4000_success_rate", JsonValue::Num(success)));
    }

    // 8. Sharded parallel engine on the 100x fleet (600 servers): the same
    //    50k-request streamed cs-ucb run at 1 shard, 4 shards, auto (one
    //    shard per tier, volume-rebalanced), and the volume-weighted plan.
    //    Results are bit-identical at every count and plan
    //    (tests/sharded_identity.rs), so the signals here are events/s —
    //    `sharded_100x_scaling_1_to_4` is the wall-clock speedup the
    //    conservative link-lookahead sync actually delivers on this
    //    machine, acceptance bar >= 2x — and `sharded_100x_imbalance`, the
    //    weighted plan's measured max/min per-shard event volume from the
    //    shard-perf telemetry (acceptance bar <= 1.25, vs >= 3 for the
    //    unbalanced tier split; see benches/README.md for the shard-
    //    balancing model and the full 1M-request command).
    {
        let topo = TopologyConfig::edgeshard_100x("llama2-7b", BandwidthMode::Stable);
        let cfg = topo.build();
        let workload = WorkloadConfig::default()
            .with_requests(50_000)
            .with_arrivals(ArrivalProcess::Poisson {
                rate: topo.scaled_rate(15.0),
            })
            .with_deadline_range(2.0, 6.0)
            .with_seed(42);
        let mut eps = [0.0f64; 4];
        let mut imbalance = 0.0f64;
        for (slot, (label, count)) in [
            ("1", ShardCount::Fixed(1)),
            ("4", ShardCount::Fixed(4)),
            ("auto", ShardCount::Auto),
            ("weighted", ShardCount::Weighted(0)),
        ]
        .into_iter()
        .enumerate()
        {
            let splan = topo.shard_plan(count);
            let mut events_per_sec = 0.0;
            let name = format!("simulate cs-ucb 50k reqs (100x, {label} shards)");
            rows.push(bench_fn(&name, 1, 3, || {
                let mut s = CsUcb::with_defaults(cfg.n_servers());
                let mut source = WorkloadGen::new(&workload);
                let rep = simulate_stream_sharded(&cfg, &splan, &mut source, &mut s);
                events_per_sec = rep.events_per_sec;
                if label == "weighted" {
                    imbalance = rep
                        .shard_perf
                        .as_ref()
                        .map(|sp| sp.imbalance)
                        .unwrap_or(f64::INFINITY);
                }
                std::hint::black_box(rep.success_rate);
            }));
            println!("  100x sharded ({label}): DES {events_per_sec:.0} events/s");
            eps[slot] = events_per_sec;
        }
        let scaling = if eps[0] > 0.0 { eps[1] / eps[0] } else { 0.0 };
        println!("  100x sharded scaling 1 -> 4 shards: {scaling:.2}x");
        println!("  100x weighted-plan measured imbalance: {imbalance:.3}");
        json.push(("sharded_100x_50k_events_per_sec_1", JsonValue::Num(eps[0])));
        json.push(("sharded_100x_50k_events_per_sec_4", JsonValue::Num(eps[1])));
        json.push(("sharded_100x_50k_events_per_sec_auto", JsonValue::Num(eps[2])));
        json.push(("sharded_100x_50k_events_per_sec_weighted", JsonValue::Num(eps[3])));
        json.push(("sharded_100x_scaling_1_to_4", JsonValue::Num(scaling)));
        json.push(("sharded_100x_imbalance", JsonValue::Num(imbalance)));
    }

    // 9. Sessioned workload on the 100x fleet: 50k multi-turn conversation
    //    turns (chat-heavy mix) streamed through the volume-weighted
    //    sharded engine under the cache-affinity scheduler. Two signals:
    //    `session_100x_50k_events_per_sec` is what the session machinery
    //    (chain heap, per-server prefix caches, KV-transfer stamping)
    //    costs on the event hot path relative to row 8's sessionless
    //    runs, and `session_100x_50k_hit_rate` is the prefix hit rate the
    //    affinity policy converts at fleet scale — the number that turns
    //    into skipped prefill (acceptance: events/s within 15% of the
    //    sessionless weighted run; hit rate > 0.2 on this mix).
    {
        let topo = TopologyConfig::edgeshard_100x("llama2-7b", BandwidthMode::Stable);
        let cfg = topo.build();
        let sessions = SessionConfig::from_workload(
            WorkloadConfig::default()
                .with_requests(50_000)
                .with_arrivals(ArrivalProcess::Poisson {
                    rate: topo.scaled_rate(15.0),
                })
                .with_per_class_slos()
                .with_class_weights([6.0, 1.0, 1.0, 2.0])
                .with_seed(42),
        );
        let splan = topo.shard_plan(ShardCount::Weighted(0));
        let mut events_per_sec = 0.0;
        let mut hit_rate = 0.0;
        let mut saved: u64 = 0;
        rows.push(bench_fn("simulate affinity 50k turns (100x, sessions)", 1, 3, || {
            let mut s = CsUcbAffinity::with_defaults(cfg.n_servers());
            let mut source = SessionSource::new(&sessions);
            let rep = simulate_stream_sharded(&cfg, &splan, &mut source, &mut s);
            events_per_sec = rep.events_per_sec;
            hit_rate = rep.cache.hit_rate().unwrap_or(0.0);
            saved = rep.cache.prefill_tokens_saved;
            std::hint::black_box(rep.success_rate);
        }));
        println!(
            "  100x sessions 50k turns: DES {events_per_sec:.0} events/s, \
             prefix hit rate {hit_rate:.3}, prefill saved {saved} tok"
        );
        json.push(("session_100x_50k_events_per_sec", JsonValue::Num(events_per_sec)));
        json.push(("session_100x_50k_hit_rate", JsonValue::Num(hit_rate)));
        json.push(("session_100x_50k_prefill_saved_tok", JsonValue::Num(saved as f64)));
    }

    println!("\n== L3 hot-path micro benches ==");
    for r in &rows {
        println!("{}", r.row());
    }

    emit_baseline(&json);
}

/// Write the measured numbers to a sibling of the committed baseline
/// (BENCH_perllm.current.json) so running the bench never clobbers the
/// history kept in BENCH_perllm.json — merge the emitted `current` section
/// in by hand when the numbers move.
fn emit_baseline(pairs: &[(&str, JsonValue)]) {
    let path = match std::env::var("PERLLM_BENCH_JSON") {
        Ok(p) if p == "skip" => return,
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_perllm.current.json")
        }
    };
    let meta = vec![
        (
            "generated_by",
            JsonValue::Str("cargo bench --bench micro_hotpath".into()),
        ),
        ("schema", JsonValue::Num(1.0)),
    ];
    let body = render_json(&[("meta", meta), ("current", pairs.to_vec())]);
    match std::fs::write(&path, body) {
        Ok(()) => println!("\nwrote baseline to {}", path.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", path.display()),
    }
}
