//! Table 1 regenerator — average success rate of meeting the personalized
//! processing-time requirement (D∆ ~ U[2 s, 6 s]) for each edge-model
//! deployment under the four methods, stable and fluctuating bandwidth.
//!
//! Paper row shape: FineInfer ~58 %, AGOD ~66-69 %, RewardlessGuidance
//! ~71-77 %, PerLLM 97-99 %.
//!
//! Run: cargo bench --bench table1_success_rate
//!      PERLLM_BENCH_REQUESTS=10000 cargo bench --bench table1_success_rate

mod common;

use perllm::bench::Table;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::sim::server::EDGE_MODELS;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    let n = common::bench_requests();
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42),
    );
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let mut table = Table::new(
            format!("Table 1: success rate %, {mode:?} bandwidth ({n} requests)"),
            &["model", "FineInfer", "AGOD", "RewardlessGuidance", "PerLLM (CS-UCB)"],
        );
        for model in EDGE_MODELS {
            let cfg = ClusterConfig::paper(model, mode);
            let mut cells = vec![model.to_string()];
            for m in common::METHODS {
                let mut s = common::make_scheduler(m, &cfg, 42);
                let rep = simulate(&cfg, &trace, s.as_mut());
                cells.push(format!("{:.0}%", rep.success_rate * 100.0));
            }
            table.row(&cells);
        }
        println!("{}", table.render());
    }
    println!("paper: 58 / 66-69 / 71-77 / 97-99 — ordering and rough gaps should match.");
}
