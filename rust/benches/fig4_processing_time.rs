//! Figure 4 regenerator — average processing time per service under the
//! four methods across model deployments, stable and fluctuating
//! bandwidth. Paper shape: PerLLM lowest everywhere; its advantage grows
//! under fluctuation.
//!
//! Run: cargo bench --bench fig4_processing_time

mod common;

use perllm::bench::Table;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::sim::server::EDGE_MODELS;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    let n = common::bench_requests();
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42),
    );
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let mut table = Table::new(
            format!("Figure 4: mean / p95 processing time (s), {mode:?} bandwidth"),
            &["model", "FineInfer", "AGOD", "RewardlessGuidance", "PerLLM (CS-UCB)"],
        );
        for model in EDGE_MODELS {
            let cfg = ClusterConfig::paper(model, mode);
            let mut cells = vec![model.to_string()];
            for m in common::METHODS {
                let mut s = common::make_scheduler(m, &cfg, 42);
                let rep = simulate(&cfg, &trace, s.as_mut());
                cells.push(format!(
                    "{:.2} / {:.2}",
                    rep.mean_processing_s, rep.p95_processing_s
                ));
            }
            table.row(&cells);
        }
        println!("{}", table.render());
    }
    println!("paper shape: PerLLM lowest mean processing time for every deployment.");
}
