//! Figure 2 regenerator — the motivation experiment: average processing
//! time and energy cost per service on cloud-only vs edge-only deployments
//! as the number of *simultaneously uploaded* services grows. The paper's
//! cloud curve surges past ~100 concurrent services (shared-uplink
//! congestion); the edge curve grows with compute saturation instead.
//!
//! Run: cargo bench --bench fig2_motivation

mod common;

use perllm::bench::Table;
use perllm::scheduler::{Action, ClusterView, Scheduler};
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::workload::generator::{generate, ArrivalProcess, WorkloadConfig};
use perllm::workload::service::ServiceRequest;

/// Fixed-tier scheduler: everything to the cloud, or round-robin over the
/// five edges (matching the paper's single-tier measurement setup).
struct Tier {
    cloud: bool,
    next_edge: usize,
}

impl Scheduler for Tier {
    fn name(&self) -> &'static str {
        if self.cloud {
            "cloud-only"
        } else {
            "edge-only"
        }
    }
    fn decide(&mut self, _r: &ServiceRequest, view: &ClusterView) -> Action {
        if self.cloud {
            Action::assign(view.servers.len() - 1)
        } else {
            let e = self.next_edge % (view.servers.len() - 1);
            self.next_edge += 1;
            Action::assign(e)
        }
    }
}

fn main() {
    let mut table = Table::new(
        "Figure 2: cloud vs edge, simultaneous service upload",
        &[
            "services", "tier", "mean tx s", "mean infer s", "mean total s",
            "J/service", "success%",
        ],
    );
    for &n in &[1usize, 10, 50, 100, 300, 600] {
        let trace = generate(
            &WorkloadConfig::default()
                .with_requests(n)
                .with_arrivals(ArrivalProcess::Simultaneous)
                .with_deadline_range(2.0, 6.0)
                .with_seed(2),
        );
        // The paper's motivation rig queues every service (no load
        // shedding) — it measures how bad the wait gets, not how much a
        // production stack would drop. Lift the queue bounds accordingly.
        let mut cfg = ClusterConfig::paper("llama2-7b", BandwidthMode::Stable);
        for srv in &mut cfg.servers {
            srv.queue_limit = 100_000;
        }
        for cloud in [true, false] {
            let mut s = Tier {
                cloud,
                next_edge: 0,
            };
            let rep = simulate(&cfg, &trace, &mut s);
            let done: Vec<_> = rep
                .outcomes
                .iter()
                .filter(|o| o.processing_time.is_finite())
                .collect();
            let mean = |f: &dyn Fn(&perllm::workload::service::ServiceOutcome) -> f64| {
                if done.is_empty() {
                    0.0
                } else {
                    done.iter().map(|o| f(o)).sum::<f64>() / done.len() as f64
                }
            };
            table.row(&[
                n.to_string(),
                if cloud { "cloud" } else { "edge" }.into(),
                format!("{:.3}", mean(&|o| o.tx_time)),
                format!("{:.3}", mean(&|o| o.infer_time)),
                format!("{:.3}", mean(&|o| o.processing_time)),
                // Per-service attributed energy (tx + marginal inference),
                // the paper's Fig-2 per-service metric.
                format!("{:.1}", mean(&|o| o.energy_j)),
                format!("{:.1}", rep.success_rate * 100.0),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "paper shape check: cloud total time + J/service surge with scale;\n\
         edge tx stays ~flat and far below cloud tx; single-service cloud is faster."
    );
}
