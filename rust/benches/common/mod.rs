//! Shared helpers for the paper-figure bench binaries.

use perllm::scheduler::{
    agod::Agod, csucb::CsUcb, fineinfer::FineInfer, rewardless::RewardlessGuidance, Scheduler,
};
use perllm::sim::cluster::ClusterConfig;

/// Trace length: full paper scale is 10 000; default trimmed for bench
/// wall-time, override with PERLLM_BENCH_REQUESTS=10000 for the record.
pub fn bench_requests() -> usize {
    std::env::var("PERLLM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2500)
}

pub const METHODS: [&str; 4] = ["fineinfer", "agod", "rewardless", "cs-ucb"];

pub fn make_scheduler(name: &str, cfg: &ClusterConfig, seed: u64) -> Box<dyn Scheduler> {
    match name {
        "fineinfer" => Box::new(FineInfer::new(cfg.cloud_index())),
        "agod" => Box::new(Agod::new(cfg.n_servers(), seed)),
        "rewardless" => Box::new(RewardlessGuidance::new(cfg.n_servers())),
        "cs-ucb" => Box::new(CsUcb::with_defaults(cfg.n_servers())),
        other => panic!("unknown method {other}"),
    }
}
