//! Figure 5 regenerator — system throughput (tokens/s) under the four
//! methods. Paper headline: PerLLM ≈ 2.2x FineInfer, 2.1x AGOD,
//! 1.6x RewardlessGuidance on average.
//!
//! Run: cargo bench --bench fig5_throughput

mod common;

use perllm::bench::Table;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::sim::server::EDGE_MODELS;
use perllm::util::stats::ratio;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    let n = common::bench_requests();
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42),
    );
    let mut ratios = vec![Vec::new(), Vec::new(), Vec::new()];
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let mut table = Table::new(
            format!("Figure 5: throughput tok/s, {mode:?} bandwidth"),
            &["model", "FineInfer", "AGOD", "RewardlessGuidance", "PerLLM (CS-UCB)"],
        );
        for model in EDGE_MODELS {
            let cfg = ClusterConfig::paper(model, mode);
            let mut cells = vec![model.to_string()];
            let mut thpts = Vec::new();
            for m in common::METHODS {
                let mut s = common::make_scheduler(m, &cfg, 42);
                let rep = simulate(&cfg, &trace, s.as_mut());
                thpts.push(rep.throughput_tok_s);
                cells.push(format!("{:.0}", rep.throughput_tok_s));
            }
            for b in 0..3 {
                ratios[b].push(ratio(thpts[3], thpts[b]));
            }
            table.row(&cells);
        }
        println!("{}", table.render());
    }
    let mean = |xs: &Vec<f64>| xs.iter().sum::<f64>() / xs.len() as f64;
    println!(
        "PerLLM average throughput ratios: {:.2}x FineInfer, {:.2}x AGOD, {:.2}x RewardlessGuidance",
        mean(&ratios[0]),
        mean(&ratios[1]),
        mean(&ratios[2])
    );
    println!("paper: 2.2x / 2.1x / 1.6x — PerLLM must win every column.");
}
