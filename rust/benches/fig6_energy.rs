//! Figure 6 regenerator — energy cost under the four methods, split into
//! the paper's three components (transmission / inference / idle), plus
//! energy per successful service (the paper's Fig-2 "per service" metric).
//! Paper headline: PerLLM reduces energy cost by more than 50 %.
//!
//! Run: cargo bench --bench fig6_energy

mod common;

use perllm::bench::Table;
use perllm::sim::cluster::{BandwidthMode, ClusterConfig};
use perllm::sim::engine::simulate;
use perllm::sim::server::EDGE_MODELS;
use perllm::workload::generator::{generate, WorkloadConfig};

fn main() {
    let n = common::bench_requests();
    let trace = generate(
        &WorkloadConfig::default()
            .with_requests(n)
            .with_deadline_range(2.0, 6.0)
            .with_seed(42),
    );
    for mode in [BandwidthMode::Stable, BandwidthMode::Fluctuating] {
        let mut table = Table::new(
            format!("Figure 6: energy kJ (tran+infer+idle) and J/successful service, {mode:?}"),
            &["model", "method", "tran kJ", "infer kJ", "idle kJ", "total kJ", "J/succ"],
        );
        for model in EDGE_MODELS {
            let cfg = ClusterConfig::paper(model, mode);
            for m in common::METHODS {
                let mut s = common::make_scheduler(m, &cfg, 42);
                let rep = simulate(&cfg, &trace, s.as_mut());
                table.row(&[
                    model.to_string(),
                    m.to_string(),
                    format!("{:.1}", rep.energy.tran_j / 1e3),
                    format!("{:.1}", rep.energy.infer_j / 1e3),
                    format!("{:.1}", rep.energy.idle_j / 1e3),
                    format!("{:.1}", rep.energy.total_j() / 1e3),
                    format!("{:.1}", rep.energy_per_success_j),
                ]);
            }
        }
        println!("{}", table.render());
    }
    println!(
        "paper shape: PerLLM's J per successful service is lowest of the edge-cloud\n\
         methods and >50% below the cloud-only FineInfer; divergence on AGOD's\n\
         absolute energy is documented in EXPERIMENTS.md."
    );
}
