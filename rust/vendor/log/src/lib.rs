//! Minimal offline shim of the `log` facade API surface this workspace
//! uses: leveled macros, `Log` trait, `set_logger`/`set_max_level`, and the
//! `Level`/`LevelFilter` pair with cross-type ordering.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Trace >= Level::Trace);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
