//! Minimal offline shim of the `anyhow` API surface this workspace uses:
//! `Error`, `Result`, `anyhow!`, `bail!`, and the `Context` extension for
//! `Result` and `Option`. Context frames chain in Display-alternate (`{:#}`)
//! form like the real crate ("outer: inner").
//!
//! Not a general replacement: no backtraces, no downcasting.

use std::fmt;

/// A boxed, context-chained error message.
pub struct Error {
    /// Context frames, outermost first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    fn push_context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message (context if any, else the root cause).
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full context chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Like real anyhow, Error deliberately does NOT implement std::error::Error
// — that keeps the blanket From below coherent with core's reflexive
// `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Sealed conversion into [`Error`], covering both std errors and `Error`
/// itself (the same split real anyhow makes with its private `ext::StdError`
/// trait) so `Context` works on `Result<_, io::Error>` and
/// `Result<_, anyhow::Error>` alike.
mod private {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Extension trait adding context to fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| private::IntoError::into_error(e).push_context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| private::IntoError::into_error(e).push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_in_alternate_display() {
        let r: Result<(), _> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_format() {
        let name = "x";
        let e = anyhow!("unknown model {name}");
        assert_eq!(format!("{e}"), "unknown model x");
        fn f() -> Result<()> {
            bail!("nope {}", 3);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 3");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("root cause"));
        let e = r.with_context(|| "outer frame").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer frame: root cause");
    }

    #[test]
    fn question_mark_converts() {
        fn g() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(g().is_err());
    }
}
