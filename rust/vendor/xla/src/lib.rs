//! Offline stub of the PJRT/XLA client API surface used by the runtime
//! layer. It compiles the full runtime code path but returns an
//! "unavailable" error from every entry point, so:
//!
//! * tier-1 builds/tests need no PJRT shared library or registry access;
//! * runtime tests (`tests/runtime_pjrt.rs`, `tests/serving_e2e.rs`) skip
//!   gracefully — they gate on AOT artifacts before touching the client;
//! * swapping in a real `xla` crate is a Cargo.toml change, no code edits.

use std::fmt;
use std::path::Path;

/// Error type matching the call sites' `map_err(|e| ...{e:?})` usage.
pub struct XlaError(String);

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError(format!(
            "{what}: PJRT is unavailable in this offline build (stub xla crate); \
             link a real xla crate to serve models"
        ))
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(XlaError::unavailable(&format!(
            "parsing HLO text {:?}",
            path.as_ref()
        )))
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::unavailable("creating PJRT CPU client"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::unavailable("compiling computation"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(XlaError::unavailable("uploading host buffer"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::unavailable("reading back buffer"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::unavailable("executing"))
    }
}

#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(XlaError::unavailable("destructuring tuple literal"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::unavailable("converting literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn everything_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
        let err = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(err.contains("unavailable"));
    }
}
